//! MiniScript recursive-descent parser: tokens -> [`Program`].

use crate::core::error::{CairlError, Result};
use crate::script::ast::*;
use crate::script::lexer::{lex, Spanned, Tok};

struct Parser {
    toks: Vec<Spanned>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn line(&self) -> u32 {
        self.toks[self.pos].line
    }

    fn advance(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, want: Tok) -> Result<()> {
        if *self.peek() == want {
            self.advance();
            Ok(())
        } else {
            Err(self.err(&format!("expected {want:?}, found {:?}", self.peek())))
        }
    }

    fn err(&self, msg: &str) -> CairlError {
        CairlError::Script(format!("parse error, line {}: {msg}", self.line()))
    }

    fn ident(&mut self) -> Result<String> {
        match self.advance() {
            Tok::Ident(s) => Ok(s),
            other => Err(self.err(&format!("expected identifier, found {other:?}"))),
        }
    }

    // ---------------------------------------------------------- program

    fn program(&mut self) -> Result<Program> {
        let mut prog = Program::default();
        while *self.peek() != Tok::Eof {
            if *self.peek() == Tok::Def {
                prog.funcs.push(self.func_def()?);
            } else {
                prog.top.push(self.statement()?);
            }
        }
        Ok(prog)
    }

    fn func_def(&mut self) -> Result<FuncDef> {
        self.expect(Tok::Def)?;
        let name = self.ident()?;
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                params.push(self.ident()?);
                if *self.peek() == Tok::Comma {
                    self.advance();
                } else {
                    break;
                }
            }
        }
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        Ok(FuncDef { name, params, body })
    }

    fn block(&mut self) -> Result<Vec<Stmt>> {
        self.expect(Tok::LBrace)?;
        let mut stmts = Vec::new();
        while *self.peek() != Tok::RBrace {
            if *self.peek() == Tok::Eof {
                return Err(self.err("unterminated block"));
            }
            stmts.push(self.statement()?);
        }
        self.expect(Tok::RBrace)?;
        Ok(stmts)
    }

    // --------------------------------------------------------- statement

    fn statement(&mut self) -> Result<Stmt> {
        match self.peek().clone() {
            Tok::If => self.if_stmt(),
            Tok::While => {
                self.advance();
                self.expect(Tok::LParen)?;
                let cond = self.expr()?;
                self.expect(Tok::RParen)?;
                let body = self.block()?;
                Ok(Stmt::While(cond, body))
            }
            Tok::For => {
                // for i = start, stop { ... }
                self.advance();
                let var = self.ident()?;
                self.expect(Tok::Assign)?;
                let start = self.expr()?;
                self.expect(Tok::Comma)?;
                let stop = self.expr()?;
                let body = self.block()?;
                Ok(Stmt::For(var, start, stop, body))
            }
            Tok::Return => {
                self.advance();
                let value = if *self.peek() == Tok::Semi {
                    None
                } else {
                    Some(self.expr()?)
                };
                self.expect(Tok::Semi)?;
                Ok(Stmt::Return(value))
            }
            Tok::Break => {
                self.advance();
                self.expect(Tok::Semi)?;
                Ok(Stmt::Break)
            }
            Tok::Continue => {
                self.advance();
                self.expect(Tok::Semi)?;
                Ok(Stmt::Continue)
            }
            Tok::Global => {
                self.advance();
                let name = self.ident()?;
                self.expect(Tok::Semi)?;
                Ok(Stmt::Global(name))
            }
            Tok::Ident(name) => {
                // Lookahead to distinguish assignment forms from bare calls.
                let save = self.pos;
                self.advance();
                match self.peek().clone() {
                    Tok::Assign => {
                        self.advance();
                        let e = self.expr()?;
                        self.expect(Tok::Semi)?;
                        Ok(Stmt::Assign(name, e))
                    }
                    Tok::PlusAssign => {
                        self.advance();
                        let e = self.expr()?;
                        self.expect(Tok::Semi)?;
                        Ok(Stmt::Assign(
                            name.clone(),
                            Expr::Bin(
                                BinOp::Add,
                                Box::new(Expr::Var(name)),
                                Box::new(e),
                            ),
                        ))
                    }
                    Tok::MinusAssign => {
                        self.advance();
                        let e = self.expr()?;
                        self.expect(Tok::Semi)?;
                        Ok(Stmt::Assign(
                            name.clone(),
                            Expr::Bin(
                                BinOp::Sub,
                                Box::new(Expr::Var(name)),
                                Box::new(e),
                            ),
                        ))
                    }
                    Tok::LBracket => {
                        self.advance();
                        let idx = self.expr()?;
                        self.expect(Tok::RBracket)?;
                        if *self.peek() == Tok::Assign {
                            self.advance();
                            let e = self.expr()?;
                            self.expect(Tok::Semi)?;
                            Ok(Stmt::IndexAssign(name, idx, e))
                        } else {
                            // An expression like xs[i] used as a statement:
                            // rewind and parse as expression statement.
                            self.pos = save;
                            let e = self.expr()?;
                            self.expect(Tok::Semi)?;
                            Ok(Stmt::Expr(e))
                        }
                    }
                    _ => {
                        self.pos = save;
                        let e = self.expr()?;
                        self.expect(Tok::Semi)?;
                        Ok(Stmt::Expr(e))
                    }
                }
            }
            _ => {
                let e = self.expr()?;
                self.expect(Tok::Semi)?;
                Ok(Stmt::Expr(e))
            }
        }
    }

    fn if_stmt(&mut self) -> Result<Stmt> {
        self.expect(Tok::If)?;
        self.expect(Tok::LParen)?;
        let cond = self.expr()?;
        self.expect(Tok::RParen)?;
        let body = self.block()?;
        let mut arms = vec![(cond, body)];
        let mut else_body = Vec::new();
        loop {
            match self.peek() {
                Tok::Elif => {
                    self.advance();
                    self.expect(Tok::LParen)?;
                    let c = self.expr()?;
                    self.expect(Tok::RParen)?;
                    let b = self.block()?;
                    arms.push((c, b));
                }
                Tok::Else => {
                    self.advance();
                    else_body = self.block()?;
                    break;
                }
                _ => break,
            }
        }
        Ok(Stmt::If { arms, else_body })
    }

    // -------------------------------------------------------- expression
    // Precedence climbing: or < and < comparison < additive <
    // multiplicative < unary < postfix < primary.

    fn expr(&mut self) -> Result<Expr> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.and_expr()?;
        while *self.peek() == Tok::Or {
            self.advance();
            let rhs = self.and_expr()?;
            lhs = Expr::Bin(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.cmp_expr()?;
        while *self.peek() == Tok::And {
            self.advance();
            let rhs = self.cmp_expr()?;
            lhs = Expr::Bin(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Eq => BinOp::Eq,
            Tok::Ne => BinOp::Ne,
            Tok::Lt => BinOp::Lt,
            Tok::Le => BinOp::Le,
            Tok::Gt => BinOp::Gt,
            Tok::Ge => BinOp::Ge,
            _ => return Ok(lhs),
        };
        self.advance();
        let rhs = self.add_expr()?;
        Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)))
    }

    fn add_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.advance();
            let rhs = self.mul_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::Percent => BinOp::Mod,
                _ => break,
            };
            self.advance();
            let rhs = self.unary_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr> {
        match self.peek() {
            Tok::Minus => {
                self.advance();
                Ok(Expr::Un(UnOp::Neg, Box::new(self.unary_expr()?)))
            }
            Tok::Not => {
                self.advance();
                Ok(Expr::Un(UnOp::Not, Box::new(self.unary_expr()?)))
            }
            _ => self.postfix_expr(),
        }
    }

    fn postfix_expr(&mut self) -> Result<Expr> {
        let mut e = self.primary()?;
        while *self.peek() == Tok::LBracket {
            self.advance();
            let idx = self.expr()?;
            self.expect(Tok::RBracket)?;
            e = Expr::Index(Box::new(e), Box::new(idx));
        }
        Ok(e)
    }

    fn primary(&mut self) -> Result<Expr> {
        match self.advance() {
            Tok::Num(v) => Ok(Expr::Num(v)),
            Tok::True => Ok(Expr::Bool(true)),
            Tok::False => Ok(Expr::Bool(false)),
            Tok::None_ => Ok(Expr::None_),
            Tok::Str(s) => Ok(Expr::Str(s)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            Tok::LBracket => {
                let mut items = Vec::new();
                if *self.peek() != Tok::RBracket {
                    loop {
                        items.push(self.expr()?);
                        if *self.peek() == Tok::Comma {
                            self.advance();
                        } else {
                            break;
                        }
                    }
                }
                self.expect(Tok::RBracket)?;
                Ok(Expr::List(items))
            }
            Tok::Ident(name) => {
                if *self.peek() == Tok::LParen {
                    self.advance();
                    let mut args = Vec::new();
                    if *self.peek() != Tok::RParen {
                        loop {
                            args.push(self.expr()?);
                            if *self.peek() == Tok::Comma {
                                self.advance();
                            } else {
                                break;
                            }
                        }
                    }
                    self.expect(Tok::RParen)?;
                    Ok(Expr::Call(name, args))
                } else {
                    Ok(Expr::Var(name))
                }
            }
            other => Err(self.err(&format!("unexpected token {other:?}"))),
        }
    }
}

/// Parse a MiniScript program from source text.
pub fn parse(src: &str) -> Result<Program> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.program()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_assignment_and_arith_with_precedence() {
        let prog = parse("x = 1 + 2 * 3;").unwrap();
        assert_eq!(prog.top.len(), 1);
        match &prog.top[0] {
            Stmt::Assign(name, Expr::Bin(BinOp::Add, _, rhs)) => {
                assert_eq!(name, "x");
                assert!(matches!(**rhs, Expr::Bin(BinOp::Mul, _, _)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_function_def() {
        let prog = parse("def f(a, b) { return a + b; }").unwrap();
        assert_eq!(prog.funcs.len(), 1);
        assert_eq!(prog.funcs[0].params, vec!["a", "b"]);
    }

    #[test]
    fn parses_if_elif_else() {
        let prog = parse(
            "def f(x) { if (x > 0) { return 1; } elif (x < 0) { return -1; } else { return 0; } }",
        )
        .unwrap();
        match &prog.funcs[0].body[0] {
            Stmt::If { arms, else_body } => {
                assert_eq!(arms.len(), 2);
                assert_eq!(else_body.len(), 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_while_and_compound_assign() {
        let prog = parse("def f() { i = 0; while (i < 10) { i += 1; } return i; }").unwrap();
        assert!(matches!(prog.funcs[0].body[1], Stmt::While(_, _)));
    }

    #[test]
    fn parses_for_loop() {
        let prog = parse("def f() { s = 0; for i = 0, 10 { s += i; } return s; }").unwrap();
        assert!(matches!(prog.funcs[0].body[1], Stmt::For(_, _, _, _)));
    }

    #[test]
    fn parses_lists_and_indexing() {
        let prog = parse("xs = [1, 2, 3]; y = xs[1]; xs[0] = 9;").unwrap();
        assert_eq!(prog.top.len(), 3);
        assert!(matches!(prog.top[2], Stmt::IndexAssign(_, _, _)));
    }

    #[test]
    fn parses_calls_and_logic() {
        let prog = parse("z = cos(1.0) and not sin(x) or y;").unwrap();
        assert_eq!(prog.top.len(), 1);
    }

    #[test]
    fn error_on_missing_semi() {
        assert!(parse("x = 1").is_err());
    }

    #[test]
    fn error_on_unterminated_block() {
        assert!(parse("def f() { x = 1;").is_err());
    }

    #[test]
    fn index_expression_statement() {
        // xs[0]; is a valid (useless) expression statement.
        assert!(parse("xs[0];").is_ok());
    }
}
