//! MiniScript abstract syntax tree.

/// Binary operators (dynamic dispatch happens in the interpreter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

/// Unary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

/// Expressions.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Num(f64),
    Bool(bool),
    Str(String),
    None_,
    /// Variable reference, resolved by name at run time (CPython-style).
    Var(String),
    List(Vec<Expr>),
    /// `xs[i]`
    Index(Box<Expr>, Box<Expr>),
    Un(UnOp, Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    /// `f(a, b, ...)` — user function or builtin, resolved at run time.
    Call(String, Vec<Expr>),
}

/// Statements.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `x = e;` — assigns in the innermost scope unless declared global.
    Assign(String, Expr),
    /// `xs[i] = e;`
    IndexAssign(String, Expr, Expr),
    /// `x += e;` / `x -= e;` desugared by the parser into Assign.
    Expr(Expr),
    If {
        /// `(condition, body)` for if/elif arms in order.
        arms: Vec<(Expr, Vec<Stmt>)>,
        else_body: Vec<Stmt>,
    },
    While(Expr, Vec<Stmt>),
    /// `for i = start, stop { ... }` — integer loop, half-open.
    For(String, Expr, Expr, Vec<Stmt>),
    Return(Option<Expr>),
    Break,
    Continue,
    /// `global x;` inside a function body.
    Global(String),
}

/// A function definition.
#[derive(Clone, Debug, PartialEq)]
pub struct FuncDef {
    pub name: String,
    pub params: Vec<String>,
    pub body: Vec<Stmt>,
}

/// A parsed program: top-level statements (run once, build globals) and
/// function definitions.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Program {
    pub top: Vec<Stmt>,
    pub funcs: Vec<FuncDef>,
}
