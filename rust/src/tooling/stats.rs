//! Summary statistics for benchmark and experiment reporting.

/// Mean / std / min / max / percentiles of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            let idx = ((n - 1) as f64 * p).round() as usize;
            sorted[idx]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.5),
            p95: pct(0.95),
        }
    }

    /// From f32 samples.
    pub fn of_f32(xs: &[f32]) -> Summary {
        let v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
        Summary::of(&v)
    }

    /// `mean +- std` one-liner for logs.
    pub fn brief(&self) -> String {
        format!("{:.4} +- {:.4} (n={})", self.mean, self.std, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_sample() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p50 <= s.p95);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 94.0).abs() <= 1.5);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }
}
