//! Tournament framework — "trivializes running single-elimination and
//! Swiss-based tournaments" (paper §III-A, Tooling).
//!
//! Generic over a match function so any two-player game plugs in; the
//! GridRTS bots ([`crate::envs::gridrts`]) are the built-in workload
//! (`examples/tournament.rs`).

use crate::core::rng::Pcg32;

/// Result of one pairing from the first player's perspective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GameOutcome {
    WinA,
    WinB,
    Draw,
}

/// Final placement row.
#[derive(Clone, Debug, PartialEq)]
pub struct Standing {
    pub player: usize,
    /// Swiss: match points (win 2, draw 1).  Single-elim: rounds survived.
    pub score: u32,
    /// Number of matches played.
    pub played: u32,
}

/// Run a single-elimination bracket over `n` players.
///
/// `play(a, b)` decides each pairing (draws are replayed with colours
/// swapped; a second draw eliminates the higher-indexed player, keeping
/// the bracket total).  Returns standings sorted best-first; the
/// champion is `standings[0].player`.
pub fn single_elimination(
    n: usize,
    rng: &mut Pcg32,
    mut play: impl FnMut(usize, usize) -> GameOutcome,
) -> Vec<Standing> {
    assert!(n >= 2);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut played = vec![0u32; n];
    let mut rounds_survived = vec![0u32; n];
    let mut alive = order;
    let mut round = 0;
    while alive.len() > 1 {
        round += 1;
        let mut next = Vec::with_capacity(alive.len() / 2 + 1);
        let mut it = alive.chunks(2);
        for pair in &mut it {
            if pair.len() == 1 {
                // Bye: advances without playing.
                rounds_survived[pair[0]] = round;
                next.push(pair[0]);
                continue;
            }
            let (a, b) = (pair[0], pair[1]);
            played[a] += 1;
            played[b] += 1;
            let winner = match play(a, b) {
                GameOutcome::WinA => a,
                GameOutcome::WinB => b,
                GameOutcome::Draw => {
                    // Replay with colours swapped.
                    played[a] += 1;
                    played[b] += 1;
                    match play(b, a) {
                        GameOutcome::WinA => b,
                        GameOutcome::WinB => a,
                        GameOutcome::Draw => a.min(b),
                    }
                }
            };
            rounds_survived[winner] = round;
            next.push(winner);
        }
        alive = next;
    }
    let mut standings: Vec<Standing> = (0..n)
        .map(|p| Standing {
            player: p,
            score: rounds_survived[p],
            played: played[p],
        })
        .collect();
    standings.sort_by(|a, b| b.score.cmp(&a.score).then(a.player.cmp(&b.player)));
    standings
}

/// Run a Swiss tournament: `rounds` rounds, players paired by standing,
/// no pair meets twice, odd player out gets a bye (2 points, once max).
pub fn swiss(
    n: usize,
    rounds: u32,
    rng: &mut Pcg32,
    mut play: impl FnMut(usize, usize) -> GameOutcome,
) -> Vec<Standing> {
    assert!(n >= 2);
    let mut points = vec![0u32; n];
    let mut played_count = vec![0u32; n];
    let mut met = vec![false; n * n];
    let mut had_bye = vec![false; n];

    for round in 0..rounds {
        // Order by points (stable shuffle inside equal scores via rng on
        // round 0 to randomise initial pairings).
        let mut order: Vec<usize> = (0..n).collect();
        if round == 0 {
            rng.shuffle(&mut order);
        } else {
            order.sort_by(|&a, &b| points[b].cmp(&points[a]).then(a.cmp(&b)));
        }
        let mut paired = vec![false; n];
        let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(n / 2);
        let mut bye: Option<usize> = None;
        for i in 0..n {
            let a = order[i];
            if paired[a] {
                continue;
            }
            // Find the highest-ranked unpaired opponent not yet met.
            let opp = order[i + 1..]
                .iter()
                .copied()
                .find(|&b| !paired[b] && !met[a * n + b]);
            match opp {
                Some(b) => {
                    paired[a] = true;
                    paired[b] = true;
                    pairs.push((a, b));
                }
                None => {
                    // No fresh opponent: bye (prefer someone without one).
                    if bye.is_none() && !had_bye[a] {
                        paired[a] = true;
                        bye = Some(a);
                    }
                }
            }
        }
        // Anyone left unpaired (rematch-locked) also byes this round.
        if bye.is_none() {
            bye = (0..n).find(|&p| !paired[p]);
        }
        if let Some(b) = bye {
            points[b] += 2;
            had_bye[b] = true;
        }
        for (a, b) in pairs {
            met[a * n + b] = true;
            met[b * n + a] = true;
            played_count[a] += 1;
            played_count[b] += 1;
            match play(a, b) {
                GameOutcome::WinA => points[a] += 2,
                GameOutcome::WinB => points[b] += 2,
                GameOutcome::Draw => {
                    points[a] += 1;
                    points[b] += 1;
                }
            }
        }
    }
    let mut standings: Vec<Standing> = (0..n)
        .map(|p| Standing {
            player: p,
            score: points[p],
            played: played_count[p],
        })
        .collect();
    standings.sort_by(|a, b| b.score.cmp(&a.score).then(a.player.cmp(&b.player)));
    standings
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic strength model: lower index always wins.
    fn by_strength(a: usize, b: usize) -> GameOutcome {
        if a < b {
            GameOutcome::WinA
        } else {
            GameOutcome::WinB
        }
    }

    #[test]
    fn single_elim_crowns_the_strongest() {
        for seed in 0..5 {
            let mut rng = Pcg32::new(seed, 1);
            let standings = single_elimination(8, &mut rng, by_strength);
            assert_eq!(standings[0].player, 0, "seed {seed}");
        }
    }

    #[test]
    fn single_elim_handles_odd_field() {
        let mut rng = Pcg32::new(1, 1);
        let standings = single_elimination(7, &mut rng, by_strength);
        assert_eq!(standings.len(), 7);
        assert_eq!(standings[0].player, 0);
        // Total matches in a 7-player knockout = 6 (ignoring draw replays).
        let total: u32 = standings.iter().map(|s| s.played).sum();
        assert_eq!(total, 12); // each match counts for both players
    }

    #[test]
    fn single_elim_draws_are_replayed() {
        let mut calls = 0;
        let mut rng = Pcg32::new(2, 1);
        let standings = single_elimination(2, &mut rng, |_, _| {
            calls += 1;
            if calls == 1 {
                GameOutcome::Draw
            } else {
                GameOutcome::WinA
            }
        });
        assert_eq!(calls, 2);
        assert_eq!(standings[0].played, 2);
    }

    #[test]
    fn swiss_ranks_by_strength() {
        let mut rng = Pcg32::new(3, 1);
        let standings = swiss(8, 3, &mut rng, by_strength);
        // Strongest two players should finish in the top half.
        let pos0 = standings.iter().position(|s| s.player == 0).unwrap();
        assert!(pos0 <= 1, "player 0 finished {pos0}: {standings:?}");
        // Weakest finishes in the bottom half.
        let pos7 = standings.iter().position(|s| s.player == 7).unwrap();
        assert!(pos7 >= 4, "{standings:?}");
    }

    #[test]
    fn swiss_no_rematches() {
        let mut seen = std::collections::HashSet::new();
        let mut rng = Pcg32::new(4, 1);
        swiss(6, 4, &mut rng, |a, b| {
            let key = (a.min(b), a.max(b));
            assert!(seen.insert(key), "rematch {key:?}");
            by_strength(a, b)
        });
    }

    #[test]
    fn swiss_odd_field_byes_are_balanced() {
        let mut rng = Pcg32::new(5, 1);
        let standings = swiss(5, 3, &mut rng, by_strength);
        // 5 players, 3 rounds: every round has exactly one bye, nobody
        // plays more than 3 matches.
        assert!(standings.iter().all(|s| s.played <= 3));
        let total_points: u32 = standings.iter().map(|s| s.score).sum();
        // Each round distributes 2 points per pair + 2 for the bye = 6.
        assert_eq!(total_points, 18);
    }

    #[test]
    fn swiss_draws_split_points() {
        let mut rng = Pcg32::new(6, 1);
        let standings = swiss(2, 1, &mut rng, |_, _| GameOutcome::Draw);
        assert_eq!(standings[0].score, 1);
        assert_eq!(standings[1].score, 1);
    }
}
