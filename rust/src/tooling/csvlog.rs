//! Structured CSV logging for experiment outputs.
//!
//! Every figure/table reproduction writes its rows through this logger,
//! giving EXPERIMENTS.md a stable on-disk provenance trail under
//! `results/`.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::core::error::Result;

/// A buffered CSV writer with a fixed header.
pub struct CsvLogger {
    path: PathBuf,
    writer: BufWriter<File>,
    columns: usize,
    rows: usize,
}

impl CsvLogger {
    /// Create (truncate) `path`, writing the header immediately.  Parent
    /// directories are created as needed.
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvLogger> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let file = File::create(path)?;
        let mut writer = BufWriter::new(file);
        writeln!(writer, "{}", header.join(","))?;
        Ok(CsvLogger {
            path: path.to_path_buf(),
            writer,
            columns: header.len(),
            rows: 0,
        })
    }

    /// Write one row of display-formatted fields.
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(
            fields.len(),
            self.columns,
            "{}: row width mismatch",
            self.path.display()
        );
        writeln!(self.writer, "{}", fields.join(","))?;
        self.rows += 1;
        Ok(())
    }

    /// Convenience: all-f64 row.
    pub fn row_f64(&mut self, fields: &[f64]) -> Result<()> {
        let formatted: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        self.row(&formatted)
    }

    /// Rows written (excluding header).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Flush to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("cairl_csv_test_{}", std::process::id()));
        let path = dir.join("sub").join("log.csv");
        let mut log = CsvLogger::create(&path, &["a", "b"]).unwrap();
        log.row(&["1".into(), "x".into()]).unwrap();
        log.row_f64(&[2.5, 3.5]).unwrap();
        log.flush().unwrap();
        assert_eq!(log.rows(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines, vec!["a,b", "1,x", "2.5,3.5"]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
