//! Tooling — the paper's §III-A module for "contributions that reach a
//! stable state": the tournament framework (single-elimination and
//! Swiss), summary statistics, and structured result logging.

pub mod csvlog;
pub mod stats;
pub mod tournament;

pub use csvlog::CsvLogger;
pub use stats::Summary;
pub use tournament::{swiss, single_elimination, Standing};
