//! RewardScale — affine reward transformation `r' = scale * r + shift`.
//!
//! Small but load-bearing: DQN on MountainCar/Acrobot benefits from
//! scaled rewards, and the flash Multitask environment uses it to map the
//! VM's score delta into the paper's +1/-1 scheme.

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Applies `reward * scale + shift` to every step.
#[derive(Clone, Debug)]
pub struct RewardScale<E: Env> {
    inner: E,
    scale: f32,
    shift: f32,
}

impl<E: Env> RewardScale<E> {
    pub fn new(inner: E, scale: f32, shift: f32) -> Self {
        RewardScale {
            inner,
            scale,
            shift,
        }
    }
}

impl<E: Env> Env for RewardScale<E> {
    fn id(&self) -> String {
        format!("RewardScale({}, x{}, +{})", self.inner.id(), self.scale, self.shift)
    }

    fn observation_space(&self) -> Space {
        self.inner.observation_space()
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.inner.reset_into(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let mut t = self.inner.step_into(action, obs);
        t.reward = t.reward * self.scale + self.shift;
        t
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::CartPole;

    #[test]
    fn scales_and_shifts() {
        let mut env = RewardScale::new(CartPole::new(), 2.0, -0.5);
        env.seed(0);
        let mut obs = vec![0.0; 4];
        env.reset_into(&mut obs);
        let t = env.step_into(&Action::Discrete(0), &mut obs);
        // CartPole reward is 1.0 -> 2.0 * 1.0 - 0.5 = 1.5.
        assert!((t.reward - 1.5).abs() < 1e-6);
    }

    #[test]
    fn identity_transform_is_transparent() {
        let mut a = RewardScale::new(CartPole::new(), 1.0, 0.0);
        let mut b = CartPole::new();
        a.seed(3);
        b.seed(3);
        let mut oa = vec![0.0; 4];
        let mut ob = vec![0.0; 4];
        a.reset_into(&mut oa);
        b.reset_into(&mut ob);
        assert_eq!(oa, ob);
        let ta = a.step_into(&Action::Discrete(1), &mut oa);
        let tb = b.step_into(&Action::Discrete(1), &mut ob);
        assert_eq!(ta, tb);
        assert_eq!(oa, ob);
    }
}
