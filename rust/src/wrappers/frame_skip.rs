//! FrameSkip — repeat each agent action for `k` environment frames,
//! accumulating reward (the DQN action-repeat of Mnih et al. 2015,
//! standard for the high-frame-rate Flash games).

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Repeats actions `k` times per agent step.
#[derive(Clone, Debug)]
pub struct FrameSkip<E: Env> {
    inner: E,
    k: u32,
}

impl<E: Env> FrameSkip<E> {
    pub fn new(inner: E, k: u32) -> Self {
        assert!(k >= 1);
        FrameSkip { inner, k }
    }
}

impl<E: Env> Env for FrameSkip<E> {
    fn id(&self) -> String {
        format!("FrameSkip({}, {})", self.inner.id(), self.k)
    }

    fn observation_space(&self) -> Space {
        self.inner.observation_space()
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.inner.reset_into(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let mut total = 0.0;
        for _ in 0..self.k {
            let t = self.inner.step_into(action, obs);
            total += t.reward;
            if t.done || t.truncated {
                return Transition {
                    reward: total,
                    done: t.done,
                    truncated: t.truncated,
                };
            }
        }
        Transition::live(total)
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{CartPole, Pendulum};
    use crate::wrappers::TimeLimit;

    #[test]
    fn accumulates_k_rewards() {
        let mut env = FrameSkip::new(TimeLimit::new(Pendulum::discrete(), 100), 4);
        env.seed(0);
        let mut obs = vec![0.0f32; 3];
        env.reset_into(&mut obs);
        let t = env.step_into(&Action::Discrete(2), &mut obs);
        // Four pendulum steps of negative cost accumulate.
        assert!(t.reward < 0.0);
        assert!(!t.done);
    }

    #[test]
    fn stops_mid_skip_on_termination() {
        let mut env = FrameSkip::new(CartPole::new(), 50);
        env.seed(0);
        let mut obs = vec![0.0f32; 4];
        env.reset_into(&mut obs);
        // Constant pushes topple the pole before 50 frames; the skip must
        // stop at the terminal frame, so reward < 50.
        let t = env.step_into(&Action::Discrete(1), &mut obs);
        assert!(t.done);
        assert!(t.reward < 50.0);
        assert!(t.reward >= 1.0);
    }

    #[test]
    fn k_one_is_identity() {
        let mut a = FrameSkip::new(CartPole::new(), 1);
        let mut b = CartPole::new();
        a.seed(5);
        b.seed(5);
        let mut oa = vec![0.0f32; 4];
        let mut ob = vec![0.0f32; 4];
        a.reset_into(&mut oa);
        b.reset_into(&mut ob);
        let ta = a.step_into(&Action::Discrete(0), &mut oa);
        let tb = b.step_into(&Action::Discrete(0), &mut ob);
        assert_eq!(ta, tb);
        assert_eq!(oa, ob);
    }
}
