//! FrameStack — concatenate the last `k` observations.
//!
//! The standard DQN trick for making velocity observable from positions
//! (Mnih et al. 2015 stack 4 Atari frames); here it works over any Box
//! observation.  The stack is a ring buffer, so a step costs one copy of
//! the newest frame plus one ordered read-out — no shifting.

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Stacks the most recent `k` observations into one flat vector
/// (oldest first).  On reset the initial observation is replicated `k`
/// times, matching Gym's FrameStack.
#[derive(Clone, Debug)]
pub struct FrameStack<E: Env> {
    inner: E,
    k: usize,
    dim: usize,
    ring: Vec<f32>,
    head: usize,
}

impl<E: Env> FrameStack<E> {
    pub fn new(inner: E, k: usize) -> Self {
        assert!(k >= 1);
        let dim = inner.obs_dim();
        FrameStack {
            inner,
            k,
            dim,
            ring: vec![0.0; dim * k],
            head: 0,
        }
    }

    /// Copy the ring out, oldest frame first.
    fn read_out(&self, obs: &mut [f32]) {
        for i in 0..self.k {
            let slot = (self.head + i) % self.k;
            obs[i * self.dim..(i + 1) * self.dim]
                .copy_from_slice(&self.ring[slot * self.dim..(slot + 1) * self.dim]);
        }
    }

    fn push(&mut self, frame: &[f32]) {
        let slot = self.head;
        self.ring[slot * self.dim..(slot + 1) * self.dim].copy_from_slice(frame);
        self.head = (self.head + 1) % self.k;
    }
}

impl<E: Env> Env for FrameStack<E> {
    fn id(&self) -> String {
        format!("FrameStack({}, {})", self.inner.id(), self.k)
    }

    fn observation_space(&self) -> Space {
        match self.inner.observation_space() {
            Space::Box { low, high, .. } => Space::Box {
                low: low.repeat(self.k),
                high: high.repeat(self.k),
                shape: vec![self.k * self.dim],
            },
            d => d,
        }
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.dim * self.k
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        let mut frame = vec![0.0; self.dim];
        self.inner.reset_into(&mut frame);
        // Replicate the first observation into every slot.
        for i in 0..self.k {
            self.ring[i * self.dim..(i + 1) * self.dim].copy_from_slice(&frame);
        }
        self.head = 0;
        self.read_out(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let mut frame = vec![0.0; self.dim];
        let t = self.inner.step_into(action, &mut frame);
        self.push(&frame);
        self.read_out(obs);
        t
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Observation = [step counter], never terminates.
    struct Counter(f32);

    impl Env for Counter {
        fn id(&self) -> String {
            "Counter-v0".into()
        }
        fn observation_space(&self) -> Space {
            Space::box1(vec![0.0], vec![1e6])
        }
        fn action_space(&self) -> Space {
            Space::Discrete { n: 1 }
        }
        fn seed(&mut self, _s: u64) {}
        fn reset_into(&mut self, obs: &mut [f32]) {
            self.0 = 0.0;
            obs[0] = 0.0;
        }
        fn step_into(&mut self, _a: &Action, obs: &mut [f32]) -> Transition {
            self.0 += 1.0;
            obs[0] = self.0;
            Transition::live(0.0)
        }
    }

    #[test]
    fn reset_replicates_first_frame() {
        let mut env = FrameStack::new(Counter(0.0), 4);
        let obs = env.reset();
        assert_eq!(obs, vec![0.0, 0.0, 0.0, 0.0]);
        assert_eq!(env.obs_dim(), 4);
    }

    #[test]
    fn stack_is_oldest_first_sliding_window() {
        let mut env = FrameStack::new(Counter(0.0), 3);
        let mut obs = vec![0.0; 3];
        env.reset_into(&mut obs);
        let a = Action::Discrete(0);
        env.step_into(&a, &mut obs);
        assert_eq!(obs, vec![0.0, 0.0, 1.0]);
        env.step_into(&a, &mut obs);
        assert_eq!(obs, vec![0.0, 1.0, 2.0]);
        env.step_into(&a, &mut obs);
        assert_eq!(obs, vec![1.0, 2.0, 3.0]);
        env.step_into(&a, &mut obs);
        assert_eq!(obs, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn space_bounds_are_repeated() {
        let env = FrameStack::new(Counter(0.0), 2);
        match env.observation_space() {
            Space::Box { low, high, shape } => {
                assert_eq!(low, vec![0.0, 0.0]);
                assert_eq!(high, vec![1e6, 1e6]);
                assert_eq!(shape, vec![2]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn k_one_is_identity() {
        let mut env = FrameStack::new(Counter(0.0), 1);
        let mut obs = vec![0.0; 1];
        env.reset_into(&mut obs);
        env.step_into(&Action::Discrete(0), &mut obs);
        assert_eq!(obs, vec![1.0]);
    }

    #[test]
    fn reset_clears_history() {
        let mut env = FrameStack::new(Counter(0.0), 3);
        let mut obs = vec![0.0; 3];
        env.reset_into(&mut obs);
        for _ in 0..5 {
            env.step_into(&Action::Discrete(0), &mut obs);
        }
        env.reset_into(&mut obs);
        assert_eq!(obs, vec![0.0, 0.0, 0.0]);
    }
}
