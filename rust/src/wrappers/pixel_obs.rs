//! PixelObs — raw-pixel observations through the software renderer.
//!
//! The paper's environments expose "either raw pixels or the virtual
//! Flash memory" (§IV-C) and the Fig.-2/Table-II experiments "use raw
//! images as input" (§V-B).  This wrapper turns *any* renderable env
//! into a pixel-observation env: each step paints the scene into an
//! internal framebuffer (the paper's software-rendering path — no GPU
//! readback) and emits a downsampled grayscale image as the flat
//! observation.

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Replaces the observation with a `size x size` grayscale frame.
pub struct PixelObs<E: Env> {
    inner: E,
    full: Framebuffer,
    small: Framebuffer,
    size: usize,
}

impl<E: Env> PixelObs<E> {
    /// `size` must divide 64 (the painters' native resolution).
    pub fn new(inner: E, size: usize) -> PixelObs<E> {
        assert!(size > 0 && 64 % size == 0, "size must divide 64");
        PixelObs {
            inner,
            full: Framebuffer::standard(),
            small: Framebuffer::new(size, size),
            size,
        }
    }

    /// The wrapped environment.
    pub fn inner(&self) -> &E {
        &self.inner
    }

    fn observe(&mut self, obs: &mut [f32]) {
        self.inner.render(&mut self.full);
        if self.size == 64 {
            obs.copy_from_slice(self.full.pixels());
        } else {
            self.full.downsample_into(&mut self.small);
            obs.copy_from_slice(self.small.pixels());
        }
    }
}

impl<E: Env> Env for PixelObs<E> {
    fn id(&self) -> String {
        format!("PixelObs({}, {}x{})", self.inner.id(), self.size, self.size)
    }

    fn observation_space(&self) -> Space {
        let n = self.size * self.size;
        Space::Box {
            low: vec![0.0; n],
            high: vec![1.0; n],
            shape: vec![self.size, self.size],
        }
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.size * self.size
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        // Inner observation is discarded; pixels are the observation.
        let mut scratch = vec![0.0f32; self.inner.obs_dim()];
        self.inner.reset_into(&mut scratch);
        self.observe(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let mut scratch = vec![0.0f32; self.inner.obs_dim()];
        let t = self.inner.step_into(action, &mut scratch);
        self.observe(obs);
        t
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::CartPole;
    use crate::wrappers::TimeLimit;

    #[test]
    fn obs_is_a_frame_in_unit_range() {
        let mut env = PixelObs::new(TimeLimit::new(CartPole::new(), 200), 16);
        env.seed(0);
        let obs = env.reset();
        assert_eq!(obs.len(), 256);
        assert_eq!(env.obs_dim(), 256);
        assert!(obs.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // The cart scene is non-empty.
        assert!(obs.iter().sum::<f32>() > 0.5);
    }

    #[test]
    fn full_resolution_matches_renderer() {
        let mut env = PixelObs::new(CartPole::new(), 64);
        env.seed(0);
        let obs = env.reset();
        let mut fb = Framebuffer::standard();
        env.render(&mut fb);
        assert_eq!(obs, fb.pixels());
    }

    #[test]
    fn pixels_track_dynamics() {
        let mut env = PixelObs::new(CartPole::new(), 32);
        env.seed(1);
        let a = env.reset();
        let mut obs = vec![0.0f32; 1024];
        for i in 0..6 {
            // Alternate pushes: the pole swings visibly without toppling.
            let t = env.step_into(&Action::Discrete(i % 2), &mut obs);
            assert!(!t.done);
        }
        assert_ne!(a, obs, "frames must change as the cart moves");
    }

    #[test]
    fn space_is_2d_box() {
        let env = PixelObs::new(CartPole::new(), 16);
        match env.observation_space() {
            Space::Box { shape, .. } => assert_eq!(shape, vec![16, 16]),
            _ => panic!(),
        }
        // Flatten composes on top for 1-D consumers.
        let flat = crate::wrappers::Flatten::new(PixelObs::new(CartPole::new(), 16));
        assert_eq!(flat.observation_space().shape(), vec![256]);
    }

    #[test]
    #[should_panic]
    fn size_must_divide_64() {
        PixelObs::new(CartPole::new(), 12);
    }
}
