//! RecordEpisodeStatistics — track episode returns/lengths and attach
//! them to the final [`Step`] of each episode.
//!
//! The coordinator's experiment orchestrator and the Fig.-2/Fig.-3
//! training drivers read convergence criteria from this wrapper (mean
//! return over a sliding window), so it keeps a bounded history.

use std::collections::VecDeque;

use crate::core::env::{Env, EpisodeStats, Step, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;
use crate::telemetry::{counter, Counter};

/// Records per-episode undiscounted return and length.
#[derive(Clone, Debug)]
pub struct RecordEpisodeStatistics<E: Env> {
    inner: E,
    ret: f32,
    len: u32,
    /// Completed episodes, most recent last (bounded).
    history: VecDeque<EpisodeStats>,
    capacity: usize,
    last: Option<EpisodeStats>,
    /// Process-wide episode tallies (`cairl_episodes_total`,
    /// `cairl_episode_steps_total`) — the fleet-level view of the same
    /// per-env stats this wrapper keeps locally.
    m_episodes: Counter,
    m_steps: Counter,
}

impl<E: Env> RecordEpisodeStatistics<E> {
    /// Keep up to `capacity` most recent episode records.
    pub fn new(inner: E, capacity: usize) -> Self {
        RecordEpisodeStatistics {
            inner,
            ret: 0.0,
            len: 0,
            history: VecDeque::with_capacity(capacity),
            capacity,
            last: None,
            m_episodes: counter("cairl_episodes_total"),
            m_steps: counter("cairl_episode_steps_total"),
        }
    }

    /// Stats of the most recently completed episode.
    pub fn last_episode(&self) -> Option<EpisodeStats> {
        self.last
    }

    /// Completed-episode history, oldest first.
    pub fn history(&self) -> impl Iterator<Item = &EpisodeStats> {
        self.history.iter()
    }

    /// Number of completed episodes observed (within capacity).
    pub fn episodes(&self) -> usize {
        self.history.len()
    }

    /// Mean return over the most recent `n` episodes (None until `n`
    /// episodes have completed) — the Fig.-2 solve criterion.
    pub fn mean_return(&self, n: usize) -> Option<f32> {
        if self.history.len() < n || n == 0 {
            return None;
        }
        let sum: f32 = self.history.iter().rev().take(n).map(|e| e.ret).sum();
        Some(sum / n as f32)
    }

    fn on_step(&mut self, t: &Transition) {
        self.ret += t.reward;
        self.len += 1;
        if t.done || t.truncated {
            let stats = EpisodeStats {
                ret: self.ret,
                len: self.len,
            };
            self.m_episodes.inc();
            self.m_steps.add(self.len as u64);
            self.last = Some(stats);
            if self.history.len() == self.capacity {
                self.history.pop_front();
            }
            self.history.push_back(stats);
        }
    }
}

impl<E: Env> Env for RecordEpisodeStatistics<E> {
    fn id(&self) -> String {
        format!("RecordEpisodeStatistics({})", self.inner.id())
    }

    fn observation_space(&self) -> Space {
        self.inner.observation_space()
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.ret = 0.0;
        self.len = 0;
        self.inner.reset_into(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let t = self.inner.step_into(action, obs);
        self.on_step(&t);
        t
    }

    /// The allocating step additionally attaches [`EpisodeStats`] on the
    /// final step of an episode (Gym's `info["episode"]`).
    fn step(&mut self, action: &Action) -> Step {
        let mut obs = vec![0.0; self.obs_dim()];
        let t = self.step_into(action, &mut obs);
        Step {
            obs,
            reward: t.reward,
            done: t.done || t.truncated,
            truncated: t.truncated,
            episode: if t.done || t.truncated { self.last } else { None },
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::Pendulum;
    use crate::wrappers::TimeLimit;

    fn fixed_episode_env(len: u32) -> RecordEpisodeStatistics<TimeLimit<Pendulum>> {
        let mut env =
            RecordEpisodeStatistics::new(TimeLimit::new(Pendulum::discrete(), len), 100);
        env.seed(0);
        env
    }

    #[test]
    fn records_return_and_length() {
        let mut env = fixed_episode_env(5);
        let mut obs = vec![0.0; 3];
        env.reset_into(&mut obs);
        let mut total = 0.0;
        for _ in 0..5 {
            let t = env.step_into(&Action::Discrete(2), &mut obs);
            total += t.reward;
        }
        let stats = env.last_episode().unwrap();
        assert_eq!(stats.len, 5);
        assert!((stats.ret - total).abs() < 1e-6);
    }

    #[test]
    fn attaches_stats_only_on_final_step() {
        let mut env = fixed_episode_env(3);
        env.reset();
        let a = Action::Discrete(0);
        assert!(env.step(&a).episode.is_none());
        assert!(env.step(&a).episode.is_none());
        let last = env.step(&a);
        assert!(last.done);
        assert!(last.episode.is_some());
        assert_eq!(last.episode.unwrap().len, 3);
    }

    #[test]
    fn mean_return_needs_enough_episodes() {
        let mut env = fixed_episode_env(2);
        let a = Action::Discrete(2);
        assert_eq!(env.mean_return(2), None);
        for _ in 0..3 {
            env.reset();
            env.step(&a);
            env.step(&a);
        }
        assert_eq!(env.episodes(), 3);
        assert!(env.mean_return(2).is_some());
        assert_eq!(env.mean_return(0), None);
    }

    #[test]
    fn history_is_bounded() {
        let mut env = RecordEpisodeStatistics::new(
            TimeLimit::new(Pendulum::discrete(), 1),
            4,
        );
        env.seed(0);
        let a = Action::Discrete(0);
        for _ in 0..10 {
            env.reset();
            env.step(&a);
        }
        assert_eq!(env.episodes(), 4);
    }
}
