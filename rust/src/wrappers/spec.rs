//! Declarative wrapper chains — wrapper composition as **data**.
//!
//! A [`WrapperSpec`] names one wrapper plus its parameters; an ordered
//! `&[WrapperSpec]` is a whole stack, applied innermost-first by
//! [`apply_wrappers`].  The same chain language serves three surfaces:
//! built-in registry entries ([`EnvSpec`]
//! (crate::coordinator::registry::EnvSpec) stores its stack as specs),
//! experiment configs (the `"wrappers"` block), and the CLI
//! (`cairl run --wrap "TimeLimit(200),NormalizeObs"`).
//!
//! The textual grammar is one item per wrapper, parameters in parens:
//! `TimeLimit(200)`, `Flatten`, `FrameStack(4)`, `FrameSkip(2)`,
//! `NormalizeObs`, `ClipReward` or `ClipReward(-1,1)`,
//! `RewardScale(0.5)` or `RewardScale(0.5,0.25)`, `RecordStats` or
//! `RecordStats(100)`, `PixelObs(16)` — chained with top-level commas.
//!
//! Declarative application costs one `Box` per wrapper (each layer
//! erases to [`DynEnv`]); the generic structs remain available for
//! zero-dispatch static composition, and `rust/tests/env_spec.rs` pins
//! that both spellings produce bit-identical trajectories.

use crate::core::batch::{AffineEpilogue, FusedChain};
use crate::core::env::DynEnv;
use crate::core::error::{CairlError, Result};
use crate::core::kwargs::Kwargs;
use crate::wrappers;

/// One wrapper layer, as data.
#[derive(Clone, Debug, PartialEq)]
pub enum WrapperSpec {
    /// [`wrappers::TimeLimit`]: truncate after `max_steps` steps.
    TimeLimit { max_steps: u32 },
    /// [`wrappers::Flatten`]: flatten the observation shape.
    Flatten,
    /// [`wrappers::FrameStack`]: stack the last `k` observations.
    FrameStack { k: usize },
    /// [`wrappers::FrameSkip`]: repeat each action `k` frames.
    FrameSkip { k: u32 },
    /// [`wrappers::NormalizeObs`]: rescale bounded dims to `[-1, 1]`.
    NormalizeObs,
    /// [`wrappers::ClipReward`]: clamp rewards into `[lo, hi]`.
    ClipReward { lo: f32, hi: f32 },
    /// [`wrappers::RewardScale`]: `r' = scale * r + shift`.
    RewardScale { scale: f32, shift: f32 },
    /// [`wrappers::RecordEpisodeStatistics`] with a bounded history.
    RecordStats { capacity: usize },
    /// [`wrappers::PixelObs`]: `size x size` grayscale pixels.
    PixelObs { size: usize },
}

impl WrapperSpec {
    /// Wrap `env` in this wrapper.
    pub fn apply(&self, env: DynEnv) -> DynEnv {
        match self {
            WrapperSpec::TimeLimit { max_steps } => {
                Box::new(wrappers::TimeLimit::new(env, *max_steps))
            }
            WrapperSpec::Flatten => Box::new(wrappers::Flatten::new(env)),
            WrapperSpec::FrameStack { k } => Box::new(wrappers::FrameStack::new(env, *k)),
            WrapperSpec::FrameSkip { k } => Box::new(wrappers::FrameSkip::new(env, *k)),
            WrapperSpec::NormalizeObs => Box::new(wrappers::NormalizeObs::new(env)),
            WrapperSpec::ClipReward { lo, hi } => {
                Box::new(wrappers::ClipReward::new(env, *lo, *hi))
            }
            WrapperSpec::RewardScale { scale, shift } => {
                Box::new(wrappers::RewardScale::new(env, *scale, *shift))
            }
            WrapperSpec::RecordStats { capacity } => {
                Box::new(wrappers::RecordEpisodeStatistics::new(env, *capacity))
            }
            WrapperSpec::PixelObs { size } => Box::new(wrappers::PixelObs::new(env, *size)),
        }
    }

    /// This spec with its parameters overridden by the reserved kwarg
    /// keys (`max_steps`, `frame_stack`, `frame_skip`, `pixels`) when
    /// present — how `"CartPole-v1?max_steps=200"` reaches the
    /// registered TimeLimit layer.  An override outside `0..=u32::MAX`
    /// is a [`CairlError::Config`], never a silent clamp.
    pub fn overridden_by(&self, kwargs: &Kwargs) -> Result<WrapperSpec> {
        let count = |key: &str, default: i64| -> Result<i64> {
            let value = kwargs.i64_or(key, default);
            if value < 0 || value > u32::MAX as i64 {
                return Err(CairlError::Config(format!(
                    "wrapper {}: kwarg {key:?} out of range: {value}",
                    self.render()
                )));
            }
            Ok(value)
        };
        Ok(match self {
            WrapperSpec::TimeLimit { max_steps } => WrapperSpec::TimeLimit {
                max_steps: count("max_steps", *max_steps as i64)? as u32,
            },
            WrapperSpec::FrameStack { k } => WrapperSpec::FrameStack {
                k: count("frame_stack", *k as i64)? as usize,
            },
            WrapperSpec::FrameSkip { k } => WrapperSpec::FrameSkip {
                k: count("frame_skip", *k as i64)? as u32,
            },
            WrapperSpec::PixelObs { size } => WrapperSpec::PixelObs {
                size: count("pixels", *size as i64)? as usize,
            },
            other => other.clone(),
        })
    }

    /// Check the parameters a constructor would otherwise `assert!` on,
    /// as a [`CairlError::Config`] — the guard [`EnvSpec`]
    /// (crate::coordinator::registry::EnvSpec)`::build` runs on the
    /// kwarg-overridden chain so a bad override is an error, not a
    /// panic inside a worker thread.
    pub fn validate(&self) -> Result<()> {
        let bad = |msg: String| {
            Err(CairlError::Config(format!("wrapper {}: {msg}", self.render())))
        };
        match self {
            WrapperSpec::TimeLimit { max_steps: 0 } => bad("max_steps must be >= 1".into()),
            WrapperSpec::FrameStack { k: 0 } => bad("k must be >= 1".into()),
            WrapperSpec::FrameSkip { k: 0 } => bad("k must be >= 1".into()),
            WrapperSpec::ClipReward { lo, hi } if lo > hi => {
                bad(format!("needs lo <= hi, got ({lo}, {hi})"))
            }
            WrapperSpec::PixelObs { size } if *size == 0 || 64 % *size != 0 => {
                bad(format!("size must divide 64, got {size}"))
            }
            _ => Ok(()),
        }
    }

    /// The wrapper chains a fused SoA batch kernel
    /// ([`FusedBatch`](crate::core::batch::FusedBatch)) can absorb, as
    /// a [`FusedChain`]: an optional `TimeLimit` (folded into the
    /// kernel's step counter) followed by at most one **trailing**
    /// affine layer — `NormalizeObs` or `RewardScale`, both pure
    /// per-lane affine maps the kernel applies as an epilogue
    /// ([`AffineEpilogue`]).  Anything else (longer chains, other
    /// wrappers, an affine layer *under* the time limit) returns `None`
    /// — those lanes fall back to
    /// [`ScalarBatch`](crate::core::batch::ScalarBatch) stepping.
    pub fn as_fused_chain(chain: &[WrapperSpec]) -> Option<FusedChain> {
        let (max_steps, trailing) = match chain {
            [WrapperSpec::TimeLimit { max_steps }, rest @ ..] => (Some(*max_steps), rest),
            rest => (None, rest),
        };
        let epilogue = match trailing {
            [] => None,
            [WrapperSpec::NormalizeObs] => Some(AffineEpilogue::NormalizeObs),
            [WrapperSpec::RewardScale { scale, shift }] => Some(AffineEpilogue::RewardScale {
                scale: *scale,
                shift: *shift,
            }),
            _ => return None,
        };
        Some(FusedChain { max_steps, epilogue })
    }

    /// Parse one item of the chain grammar (see the module docs).
    pub fn parse(src: &str) -> Result<WrapperSpec> {
        let bad = |msg: String| CairlError::Config(format!("wrapper spec {src:?}: {msg}"));
        let s = src.trim();
        let (name, args): (&str, Vec<&str>) = match s.split_once('(') {
            Some((name, rest)) => {
                let Some(inner) = rest.trim_end().strip_suffix(')') else {
                    return Err(bad("missing closing paren".into()));
                };
                let args = if inner.trim().is_empty() {
                    Vec::new()
                } else {
                    inner.split(',').map(str::trim).collect()
                };
                (name.trim(), args)
            }
            None => (s, Vec::new()),
        };
        let num_f32 = |raw: &str| -> Result<f32> {
            raw.parse::<f32>()
                .map_err(|_| bad(format!("bad number {raw:?}")))
        };
        let num_u32 = |raw: &str| -> Result<u32> {
            raw.parse::<u32>()
                .map_err(|_| bad(format!("bad count {raw:?}")))
        };
        match (name, args.as_slice()) {
            ("TimeLimit", [n]) => {
                let max_steps = num_u32(n)?;
                if max_steps == 0 {
                    return Err(bad("TimeLimit needs max_steps >= 1".into()));
                }
                Ok(WrapperSpec::TimeLimit { max_steps })
            }
            ("Flatten", []) => Ok(WrapperSpec::Flatten),
            ("FrameStack", [k]) => {
                let k = num_u32(k)? as usize;
                if k == 0 {
                    return Err(bad("FrameStack needs k >= 1".into()));
                }
                Ok(WrapperSpec::FrameStack { k })
            }
            ("FrameSkip", [k]) => {
                let k = num_u32(k)?;
                if k == 0 {
                    return Err(bad("FrameSkip needs k >= 1".into()));
                }
                Ok(WrapperSpec::FrameSkip { k })
            }
            ("NormalizeObs", []) => Ok(WrapperSpec::NormalizeObs),
            ("ClipReward", []) => Ok(WrapperSpec::ClipReward { lo: -1.0, hi: 1.0 }),
            ("ClipReward", [lo, hi]) => {
                let (lo, hi) = (num_f32(lo)?, num_f32(hi)?);
                if lo > hi {
                    return Err(bad(format!("ClipReward needs lo <= hi, got ({lo}, {hi})")));
                }
                Ok(WrapperSpec::ClipReward { lo, hi })
            }
            ("RewardScale", [scale]) => Ok(WrapperSpec::RewardScale {
                scale: num_f32(scale)?,
                shift: 0.0,
            }),
            ("RewardScale", [scale, shift]) => Ok(WrapperSpec::RewardScale {
                scale: num_f32(scale)?,
                shift: num_f32(shift)?,
            }),
            ("RecordStats", []) => Ok(WrapperSpec::RecordStats { capacity: 100 }),
            ("RecordStats", [capacity]) => Ok(WrapperSpec::RecordStats {
                capacity: num_u32(capacity)? as usize,
            }),
            ("PixelObs", [size]) => {
                let size = num_u32(size)? as usize;
                if size == 0 || 64 % size != 0 {
                    return Err(bad(format!("PixelObs size must divide 64, got {size}")));
                }
                Ok(WrapperSpec::PixelObs { size })
            }
            _ => Err(bad(
                "expected TimeLimit(n) | Flatten | FrameStack(k) | FrameSkip(k) | \
                 NormalizeObs | ClipReward[(lo,hi)] | RewardScale(scale[,shift]) | \
                 RecordStats[(cap)] | PixelObs(size)"
                    .into(),
            )),
        }
    }

    /// Parse a whole chain, `"TimeLimit(200),ClipReward(-1,1)"` —
    /// top-level commas separate items, commas inside parens are
    /// parameter separators.  The empty string is the empty chain.
    pub fn parse_chain(src: &str) -> Result<Vec<WrapperSpec>> {
        let src = src.trim();
        if src.is_empty() {
            return Ok(Vec::new());
        }
        split_top_level(src, ',')
            .into_iter()
            .map(WrapperSpec::parse)
            .collect()
    }

    /// Render back to the canonical item spelling
    /// (`parse(render()) == self`).
    pub fn render(&self) -> String {
        match self {
            WrapperSpec::TimeLimit { max_steps } => format!("TimeLimit({max_steps})"),
            WrapperSpec::Flatten => "Flatten".into(),
            WrapperSpec::FrameStack { k } => format!("FrameStack({k})"),
            WrapperSpec::FrameSkip { k } => format!("FrameSkip({k})"),
            WrapperSpec::NormalizeObs => "NormalizeObs".into(),
            WrapperSpec::ClipReward { lo, hi } => format!("ClipReward({lo},{hi})"),
            WrapperSpec::RewardScale { scale, shift } => {
                format!("RewardScale({scale},{shift})")
            }
            WrapperSpec::RecordStats { capacity } => format!("RecordStats({capacity})"),
            WrapperSpec::PixelObs { size } => format!("PixelObs({size})"),
        }
    }

    /// Render a whole chain with top-level comma separators.
    pub fn render_chain(chain: &[WrapperSpec]) -> String {
        chain
            .iter()
            .map(WrapperSpec::render)
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Apply a declarative chain innermost-first: `[A, B]` produces
/// `B(A(env))`, mirroring `B::new(A::new(env))`.
pub fn apply_wrappers(env: DynEnv, chain: &[WrapperSpec]) -> DynEnv {
    chain.iter().fold(env, |env, spec| spec.apply(env))
}

/// Split on `sep` at paren depth zero only.  pub(crate): the mixture
/// grammar ([`crate::coordinator::registry::MixtureSpec`]) reuses this
/// to split components and their `+`-joined wrapper chains without
/// breaking inside wrapper argument lists like `ClipReward(-1,1)`.
pub(crate) fn split_top_level(src: &str, sep: char) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in src.char_indices() {
        if c == '(' {
            depth += 1;
        } else if c == ')' {
            depth = depth.saturating_sub(1);
        } else if c == sep && depth == 0 {
            parts.push(&src[start..i]);
            start = i + c.len_utf8();
        }
    }
    parts.push(&src[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::env::Env;
    use crate::core::kwargs::{Kwargs, KwargValue};
    use crate::envs::CartPole;

    #[test]
    fn every_item_round_trips_through_the_grammar() {
        let chain = vec![
            WrapperSpec::TimeLimit { max_steps: 200 },
            WrapperSpec::Flatten,
            WrapperSpec::FrameStack { k: 4 },
            WrapperSpec::FrameSkip { k: 2 },
            WrapperSpec::NormalizeObs,
            WrapperSpec::ClipReward { lo: -1.0, hi: 1.0 },
            WrapperSpec::RewardScale { scale: 0.5, shift: 0.25 },
            WrapperSpec::RecordStats { capacity: 100 },
            WrapperSpec::PixelObs { size: 16 },
        ];
        let rendered = WrapperSpec::render_chain(&chain);
        assert_eq!(WrapperSpec::parse_chain(&rendered).unwrap(), chain);
    }

    #[test]
    fn chain_parse_respects_parens_and_defaults() {
        let chain =
            WrapperSpec::parse_chain("TimeLimit(100), ClipReward(-0.5, 0.5), RecordStats")
                .unwrap();
        assert_eq!(chain.len(), 3);
        assert_eq!(chain[0], WrapperSpec::TimeLimit { max_steps: 100 });
        assert_eq!(chain[1], WrapperSpec::ClipReward { lo: -0.5, hi: 0.5 });
        assert_eq!(chain[2], WrapperSpec::RecordStats { capacity: 100 });
        assert!(WrapperSpec::parse_chain("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "TimeLimit",
            "TimeLimit(0)",
            "TimeLimit(abc)",
            "TimeLimit(1",
            "Nope(3)",
            "ClipReward(1,-1)",
            "FrameStack(0)",
            "PixelObs(7)",
            "Flatten(1)",
        ] {
            assert!(WrapperSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn apply_wrappers_composes_innermost_first() {
        let env: crate::core::env::DynEnv = Box::new(CartPole::new());
        let wrapped = apply_wrappers(
            env,
            &[
                WrapperSpec::TimeLimit { max_steps: 100 },
                WrapperSpec::NormalizeObs,
            ],
        );
        assert_eq!(wrapped.id(), "NormalizeObs(TimeLimit(CartPole-v1, 100))");
    }

    #[test]
    fn kwarg_overrides_reach_the_right_items() {
        let kwargs = Kwargs::new()
            .with("max_steps", KwargValue::Int(33))
            .with("pixels", KwargValue::Int(8));
        let chain = [
            WrapperSpec::TimeLimit { max_steps: 500 },
            WrapperSpec::PixelObs { size: 16 },
            WrapperSpec::NormalizeObs,
        ];
        let eff: Vec<_> = chain.iter().map(|w| w.overridden_by(&kwargs).unwrap()).collect();
        assert_eq!(eff[0], WrapperSpec::TimeLimit { max_steps: 33 });
        assert_eq!(eff[1], WrapperSpec::PixelObs { size: 8 });
        assert_eq!(eff[2], WrapperSpec::NormalizeObs);
    }

    #[test]
    fn fused_chain_absorbs_a_single_trailing_affine_layer() {
        use crate::core::batch::{AffineEpilogue, FusedChain};
        assert_eq!(
            WrapperSpec::as_fused_chain(&[]),
            Some(FusedChain {
                max_steps: None,
                epilogue: None,
            })
        );
        assert_eq!(
            WrapperSpec::as_fused_chain(&[WrapperSpec::TimeLimit { max_steps: 500 }]),
            Some(FusedChain {
                max_steps: Some(500),
                epilogue: None,
            })
        );
        assert_eq!(
            WrapperSpec::as_fused_chain(&[
                WrapperSpec::TimeLimit { max_steps: 500 },
                WrapperSpec::PixelObs { size: 16 },
            ]),
            None
        );
        assert_eq!(
            WrapperSpec::as_fused_chain(&[
                WrapperSpec::TimeLimit { max_steps: 200 },
                WrapperSpec::NormalizeObs,
            ]),
            Some(FusedChain {
                max_steps: Some(200),
                epilogue: Some(AffineEpilogue::NormalizeObs),
            })
        );
        assert_eq!(
            WrapperSpec::as_fused_chain(&[WrapperSpec::RewardScale {
                scale: 0.5,
                shift: 0.25,
            }]),
            Some(FusedChain {
                max_steps: None,
                epilogue: Some(AffineEpilogue::RewardScale {
                    scale: 0.5,
                    shift: 0.25,
                }),
            })
        );
        // Longer chains, other wrappers, or an affine layer *under* the
        // time limit all fall back.
        for chain in [
            &[WrapperSpec::NormalizeObs, WrapperSpec::NormalizeObs][..],
            &[
                WrapperSpec::TimeLimit { max_steps: 200 },
                WrapperSpec::NormalizeObs,
                WrapperSpec::RewardScale { scale: 1.0, shift: 0.0 },
            ][..],
            &[WrapperSpec::NormalizeObs, WrapperSpec::TimeLimit { max_steps: 200 }][..],
            &[WrapperSpec::ClipReward { lo: -1.0, hi: 1.0 }][..],
        ] {
            assert_eq!(WrapperSpec::as_fused_chain(chain), None, "{chain:?}");
        }
    }

    #[test]
    fn out_of_range_overrides_error_instead_of_clamping() {
        let spec = WrapperSpec::TimeLimit { max_steps: 500 };
        for bad in [-1i64, i64::from(u32::MAX) + 1, i64::MAX] {
            let kwargs = Kwargs::new().with("max_steps", KwargValue::Int(bad));
            let err = spec.overridden_by(&kwargs).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{bad}: {err}");
        }
        let fine = Kwargs::new();
        assert_eq!(spec.overridden_by(&fine).unwrap(), spec);
    }
}
