//! NormalizeObs — rescale Box observations to `[-1, 1]` using the space
//! bounds (static normalisation, no running statistics, so trajectories
//! stay deterministic and reproducible).
//!
//! Unbounded dimensions (`|bound| >= f32::MAX`, e.g. CartPole velocities)
//! are passed through unchanged.

use crate::core::batch::ObsAffine;
use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Linearly maps each bounded observation dimension to `[-1, 1]`.
///
/// The affine factors live in [`ObsAffine`], which is also what the
/// fused batch kernels apply as an epilogue — one arithmetic, two
/// call sites, bit-identical by construction.
#[derive(Clone, Debug)]
pub struct NormalizeObs<E: Env> {
    inner: E,
    affine: ObsAffine,
}

impl<E: Env> NormalizeObs<E> {
    pub fn new(inner: E) -> Self {
        let affine = ObsAffine::from_space(&inner.observation_space());
        NormalizeObs { inner, affine }
    }

    #[inline]
    fn apply(&self, obs: &mut [f32]) {
        self.affine.apply(obs);
    }
}

impl<E: Env> Env for NormalizeObs<E> {
    fn id(&self) -> String {
        format!("NormalizeObs({})", self.inner.id())
    }

    fn observation_space(&self) -> Space {
        match self.inner.observation_space() {
            Space::Box { low, high, shape } => {
                let (lo2, hi2) = low
                    .iter()
                    .zip(&high)
                    .enumerate()
                    .map(|(i, (&lo, &hi))| {
                        if self.affine.is_bounded(i) {
                            (-1.0, 1.0)
                        } else {
                            (lo, hi)
                        }
                    })
                    .unzip();
                Space::Box {
                    low: lo2,
                    high: hi2,
                    shape,
                }
            }
            d => d,
        }
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.inner.reset_into(obs);
        self.apply(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let t = self.inner.step_into(action, obs);
        self.apply(obs);
        t
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{MountainCar, Pendulum};

    #[test]
    fn bounded_dims_map_to_unit_interval() {
        let mut env = NormalizeObs::new(MountainCar::new());
        env.seed(0);
        let obs = env.reset();
        // Start position in [-0.6, -0.4] maps inside [-1, 1].
        assert!(obs.iter().all(|v| (-1.0..=1.0).contains(v)), "{obs:?}");
    }

    #[test]
    fn space_reports_normalised_bounds() {
        let env = NormalizeObs::new(Pendulum::new());
        match env.observation_space() {
            Space::Box { low, high, .. } => {
                assert!(low.iter().all(|&v| v == -1.0));
                assert!(high.iter().all(|&v| v == 1.0));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn midpoint_maps_to_zero() {
        // MountainCar position midpoint is (-1.2 + 0.6)/2 = -0.3.
        let mut env = NormalizeObs::new(MountainCar::new());
        env.inner.set_state([-0.3, 0.0]);
        let mut obs = [0.0f32; 2];
        let t = env.step_into(&Action::Discrete(1), &mut obs);
        assert!(!t.done);
        // After one coast step near the midpoint, still near zero.
        assert!(obs[0].abs() < 0.05, "{obs:?}");
    }

    #[test]
    fn unbounded_dims_untouched() {
        use crate::envs::CartPole;
        let mut env = NormalizeObs::new(CartPole::new());
        env.inner.set_state([0.0, 3.5, 0.0, -2.0]);
        let mut obs = [0.0f32; 4];
        env.step_into(&Action::Discrete(0), &mut obs);
        // Velocity dims (1, 3) pass through with their raw magnitudes.
        assert!(obs[1].abs() > 1.0 || obs[3].abs() > 1.0);
    }
}
