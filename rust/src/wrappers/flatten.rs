//! Flatten — present any Box observation as a flat 1-D vector.
//!
//! One of the two wrappers the paper ships in its initial release
//! (§III-A: "wrappers to flatten the state observation").  Observations
//! are already stored flat in this toolkit, so the wrapper's job is the
//! *space* transformation: downstream code sees `shape == [n]` regardless
//! of the inner env's tensor shape.

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Flattens the observation space to 1-D.
#[derive(Clone, Debug)]
pub struct Flatten<E: Env> {
    inner: E,
}

impl<E: Env> Flatten<E> {
    pub fn new(inner: E) -> Self {
        Flatten { inner }
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }
}

impl<E: Env> Env for Flatten<E> {
    fn id(&self) -> String {
        format!("Flatten({})", self.inner.id())
    }

    fn observation_space(&self) -> Space {
        match self.inner.observation_space() {
            Space::Box { low, high, shape } => {
                let n = shape.iter().product();
                Space::Box {
                    low,
                    high,
                    shape: vec![n],
                }
            }
            d @ Space::Discrete { .. } => {
                // A discrete observation flattens to a single f32 cell.
                let n = d.flat_dim();
                Space::Box {
                    low: vec![f32::MIN; n],
                    high: vec![f32::MAX; n],
                    shape: vec![n],
                }
            }
        }
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.inner.reset_into(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        self.inner.step_into(action, obs)
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;
    use crate::envs::CartPole;
    use crate::wrappers::TimeLimit;

    /// Env with a 2-D observation space to make flattening observable.
    struct Grid2D;

    impl Env for Grid2D {
        fn id(&self) -> String {
            "Grid2D-v0".into()
        }
        fn observation_space(&self) -> Space {
            Space::Box {
                low: vec![0.0; 6],
                high: vec![1.0; 6],
                shape: vec![2, 3],
            }
        }
        fn action_space(&self) -> Space {
            Space::Discrete { n: 1 }
        }
        fn seed(&mut self, _s: u64) {}
        fn reset_into(&mut self, obs: &mut [f32]) {
            for (i, o) in obs.iter_mut().enumerate() {
                *o = i as f32 / 10.0;
            }
        }
        fn step_into(&mut self, _a: &Action, obs: &mut [f32]) -> Transition {
            self.reset_into(obs);
            Transition::live(0.0)
        }
    }

    #[test]
    fn flattens_shape_preserving_elements() {
        let env = Flatten::new(Grid2D);
        match env.observation_space() {
            Space::Box { shape, low, .. } => {
                assert_eq!(shape, vec![6]);
                assert_eq!(low.len(), 6);
            }
            _ => panic!("expected box"),
        }
        assert_eq!(env.obs_dim(), 6);
    }

    #[test]
    fn values_pass_through_in_order() {
        let mut env = Flatten::new(Grid2D);
        let obs = env.reset();
        assert_eq!(obs, vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5]);
    }

    #[test]
    fn listing1_composition_compiles_and_runs() {
        // The paper's Listing 1: Flatten<TimeLimit<200, CartPoleEnv>>.
        let mut env = Flatten::new(TimeLimit::new(CartPole::new(), 200));
        env.seed(0);
        let mut rng = Pcg32::new(0, 1);
        let (ret, len) = crate::core::env::random_rollout(&mut env, &mut rng, 500);
        assert!(len <= 200);
        assert_eq!(ret, len as f32);
        assert_eq!(env.id(), "Flatten(TimeLimit(CartPole-v1, 200))");
    }
}
