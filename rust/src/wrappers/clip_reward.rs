//! ClipReward — clamp rewards into `[lo, hi]` (DQN's reward clipping;
//! tames the Flash games' −10 death bursts for value-scale stability).

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Clamps every reward to `[lo, hi]`.
#[derive(Clone, Debug)]
pub struct ClipReward<E: Env> {
    inner: E,
    lo: f32,
    hi: f32,
}

impl<E: Env> ClipReward<E> {
    pub fn new(inner: E, lo: f32, hi: f32) -> Self {
        assert!(lo <= hi);
        ClipReward { inner, lo, hi }
    }

    /// The Mnih et al. convention: `[-1, 1]`.
    pub fn unit(inner: E) -> Self {
        Self::new(inner, -1.0, 1.0)
    }
}

impl<E: Env> Env for ClipReward<E> {
    fn id(&self) -> String {
        format!("ClipReward({}, [{}, {}])", self.inner.id(), self.lo, self.hi)
    }

    fn observation_space(&self) -> Space {
        self.inner.observation_space()
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.inner.reset_into(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let mut t = self.inner.step_into(action, obs);
        t.reward = t.reward.clamp(self.lo, self.hi);
        t
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flash::games;

    #[test]
    fn clips_death_burst() {
        // Drive Multitask to a miss; the raw -10 burst clips to -1.
        let mut env = ClipReward::unit(games::multitask());
        env.seed(3);
        let mut obs = vec![0.0f32; 32];
        env.reset_into(&mut obs);
        let mut saw_terminal = false;
        for _ in 0..20_000 {
            let t = env.step_into(&Action::Discrete(0), &mut obs);
            assert!(t.reward >= -1.0 && t.reward <= 1.0, "{}", t.reward);
            if t.done {
                saw_terminal = true;
                assert_eq!(t.reward, -1.0);
                break;
            }
        }
        assert!(saw_terminal);
    }

    #[test]
    fn passes_in_range_rewards() {
        use crate::envs::CartPole;
        let mut env = ClipReward::new(CartPole::new(), -5.0, 5.0);
        env.seed(0);
        let mut obs = vec![0.0f32; 4];
        env.reset_into(&mut obs);
        let t = env.step_into(&Action::Discrete(0), &mut obs);
        assert_eq!(t.reward, 1.0);
    }
}
