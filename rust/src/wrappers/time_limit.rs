//! TimeLimit — truncate episodes after a maximum number of steps.
//!
//! The paper's first example wrapper (`TimeLimit<200, CartPoleEnv>` in
//! Listing 1).  Truncation is reported via [`Transition::truncated`], kept
//! distinct from environment termination exactly as Gym does, because the
//! DQN bootstrap must *not* zero the value of a truncated next state.

use crate::core::env::{Env, Transition};
use crate::core::spaces::{Action, Space};
use crate::render::Framebuffer;

/// Ends episodes after `max_steps` environment steps.
#[derive(Clone, Debug)]
pub struct TimeLimit<E: Env> {
    inner: E,
    max_steps: u32,
    elapsed: u32,
}

impl<E: Env> TimeLimit<E> {
    pub fn new(inner: E, max_steps: u32) -> Self {
        TimeLimit {
            inner,
            max_steps,
            elapsed: 0,
        }
    }

    /// Steps taken in the current episode.
    pub fn elapsed(&self) -> u32 {
        self.elapsed
    }

    /// The wrapped environment.
    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }
}

impl<E: Env> Env for TimeLimit<E> {
    fn id(&self) -> String {
        format!("TimeLimit({}, {})", self.inner.id(), self.max_steps)
    }

    fn observation_space(&self) -> Space {
        self.inner.observation_space()
    }

    fn action_space(&self) -> Space {
        self.inner.action_space()
    }

    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn seed(&mut self, seed: u64) {
        self.inner.seed(seed);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        self.elapsed = 0;
        self.inner.reset_into(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let mut t = self.inner.step_into(action, obs);
        self.elapsed += 1;
        if self.elapsed >= self.max_steps && !t.done {
            t.truncated = true;
        }
        t
    }

    fn render(&self, fb: &mut Framebuffer) {
        self.inner.render(fb);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{CartPole, Pendulum};

    #[test]
    fn truncates_at_limit() {
        let mut env = TimeLimit::new(Pendulum::discrete(), 10);
        env.seed(0);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset_into(&mut obs);
        for i in 1..=10 {
            let t = env.step_into(&Action::Discrete(2), &mut obs);
            if i < 10 {
                assert!(!t.done && !t.truncated);
            } else {
                assert!(t.truncated);
                assert!(!t.done, "truncation is not termination");
            }
        }
    }

    #[test]
    fn reset_clears_counter() {
        let mut env = TimeLimit::new(Pendulum::discrete(), 5);
        env.seed(0);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset_into(&mut obs);
        for _ in 0..5 {
            env.step_into(&Action::Discrete(0), &mut obs);
        }
        env.reset_into(&mut obs);
        assert_eq!(env.elapsed(), 0);
        let t = env.step_into(&Action::Discrete(0), &mut obs);
        assert!(!t.truncated);
    }

    #[test]
    fn natural_termination_is_not_truncation() {
        let mut env = TimeLimit::new(CartPole::new(), 10_000);
        env.seed(0);
        let mut obs = vec![0.0; 4];
        env.reset_into(&mut obs);
        // Constant pushes right topple the pole well before 10k steps.
        loop {
            let t = env.step_into(&Action::Discrete(1), &mut obs);
            if t.done || t.truncated {
                assert!(t.done);
                assert!(!t.truncated);
                break;
            }
        }
    }

    #[test]
    fn id_describes_composition() {
        let env = TimeLimit::new(CartPole::new(), 200);
        assert_eq!(env.id(), "TimeLimit(CartPole-v1, 200)");
    }
}
