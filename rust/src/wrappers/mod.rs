//! Wrappers — the paper's §III-A "Wrappers" module.
//!
//! Every wrapper is a generic struct `W<E: Env>` implementing [`Env`], so
//! compositions like `Flatten<TimeLimit<CartPole>>` (paper Listing 1)
//! monomorphise to straight-line code with zero dynamic dispatch — the
//! Rust equivalent of the paper's C++ template evaluation at compile
//! time.  Because `Box<dyn Env>` also implements `Env`, the same wrappers
//! compose over the dynamic registry (`TimeLimit::new(make(..)?, 200)`),
//! at the cost of one vtable call per step; `benches/ablation_dispatch.rs`
//! measures exactly that trade-off.

//! Wrapper composition is also available as **data**: a
//! [`WrapperSpec`] chain (`"TimeLimit(200),NormalizeObs"`) names the
//! same stack declaratively, applied by [`apply_wrappers`] — the form
//! the dynamic registry ([`crate::coordinator::registry::EnvSpec`]),
//! experiment configs and `cairl run --wrap` consume.

pub mod clip_reward;
pub mod flatten;
pub mod frame_skip;
pub mod frame_stack;
pub mod normalize;
pub mod pixel_obs;
pub mod record_stats;
pub mod reward_scale;
pub mod spec;
pub mod time_limit;

pub use clip_reward::ClipReward;
pub use flatten::Flatten;
pub use frame_skip::FrameSkip;
pub use frame_stack::FrameStack;
pub use normalize::NormalizeObs;
pub use pixel_obs::PixelObs;
pub use record_stats::RecordEpisodeStatistics;
pub use reward_scale::RewardScale;
pub use spec::{apply_wrappers, WrapperSpec};
pub use time_limit::TimeLimit;
