//! The puzzle runtime — the paper's §IV-D Simon-Tatham-collection
//! integration, rebuilt as three native puzzles.
//!
//! Every puzzle ships with a **heuristic/exact solver** ("All puzzles
//! include a heuristic-based solver, enabling transfer and curriculum
//! learning research"): the solvers generate demonstration trajectories
//! and certify that every generated instance is solvable, which the
//! curriculum example (`examples/puzzle_curriculum.rs`) builds on.

pub mod fifteen;
pub mod lightsout;
pub mod nonogram;

pub use fifteen::Fifteen;
pub use lightsout::LightsOut;
pub use nonogram::Nonogram;
