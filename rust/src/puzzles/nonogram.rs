//! Nonogram (picross): fill cells so every row/column matches its
//! run-length clues.
//!
//! The paper cites nonograms as an RL-solvable puzzle class [30]; the
//! solver here is the classic line-propagation + backtracking exact
//! solver, used to certify generated instances and to produce
//! demonstration trajectories.

use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{raster, Framebuffer};

const N: usize = 5;
/// Maximum number of runs a length-5 line can have.
const MAX_RUNS: usize = 3;

/// Run-length clues of one line (e.g. `[2, 1]` = a run of 2 then 1).
pub type Clue = Vec<u8>;

/// Compute the run-length clue of a line of cells.
pub fn clue_of(line: &[bool]) -> Clue {
    let mut clue = Vec::new();
    let mut run = 0u8;
    for &c in line {
        if c {
            run += 1;
        } else if run > 0 {
            clue.push(run);
            run = 0;
        }
    }
    if run > 0 {
        clue.push(run);
    }
    clue
}

/// All bitmask placements of a clue within a line of width `N`.
fn placements(clue: &[u8]) -> Vec<u32> {
    fn rec(clue: &[u8], pos: usize, acc: u32, out: &mut Vec<u32>) {
        match clue.split_first() {
            None => out.push(acc),
            Some((&run, rest)) => {
                let run = run as usize;
                let tail: usize =
                    rest.iter().map(|&r| r as usize + 1).sum::<usize>();
                if pos + run + tail > N {
                    return;
                }
                for start in pos..=(N - run - tail) {
                    let mask = ((1u32 << run) - 1) << start;
                    let next = start + run + 1;
                    rec(rest, next, acc | mask, out);
                }
            }
        }
    }
    let mut out = Vec::new();
    rec(clue, 0, 0, &mut out);
    out
}

/// A 5x5 nonogram instance.
#[derive(Clone, Debug)]
pub struct Nonogram {
    row_clues: Vec<Clue>,
    col_clues: Vec<Clue>,
    grid: Vec<bool>,
    moves: u32,
    rng: Pcg32,
    fill_p: f32,
}

impl Nonogram {
    pub fn new() -> Nonogram {
        Nonogram {
            row_clues: vec![Vec::new(); N],
            col_clues: vec![Vec::new(); N],
            grid: vec![false; N * N],
            moves: 0,
            rng: Pcg32::new(0, 0x9fb21c651e98df25),
            fill_p: 0.55,
        }
    }

    /// Registered env variant.
    pub fn env() -> Nonogram {
        Nonogram::new()
    }

    pub fn grid(&self) -> &[bool] {
        &self.grid
    }

    pub fn row_clues(&self) -> &[Clue] {
        &self.row_clues
    }

    pub fn col_clues(&self) -> &[Clue] {
        &self.col_clues
    }

    fn row(&self, r: usize) -> Vec<bool> {
        self.grid[r * N..(r + 1) * N].to_vec()
    }

    fn col(&self, c: usize) -> Vec<bool> {
        (0..N).map(|r| self.grid[r * N + c]).collect()
    }

    /// Does the current grid satisfy every clue?
    pub fn solved(&self) -> bool {
        (0..N).all(|r| clue_of(&self.row(r)) == self.row_clues[r])
            && (0..N).all(|c| clue_of(&self.col(c)) == self.col_clues[c])
    }

    /// Number of satisfied lines (reward shaping / curriculum metric).
    pub fn satisfied_lines(&self) -> usize {
        (0..N).filter(|&r| clue_of(&self.row(r)) == self.row_clues[r]).count()
            + (0..N).filter(|&c| clue_of(&self.col(c)) == self.col_clues[c]).count()
    }

    /// Exact solver: line propagation with backtracking.  Returns a
    /// satisfying grid as a bool vec, or None.
    pub fn solve(&self) -> Option<Vec<bool>> {
        // Candidate masks per row, filtered progressively by column
        // constraints via depth-first search over rows.
        let row_cands: Vec<Vec<u32>> =
            self.row_clues.iter().map(|c| placements(c)).collect();
        let col_cands: Vec<Vec<u32>> =
            self.col_clues.iter().map(|c| placements(c)).collect();
        // Column masks as sets for O(1) final check.
        fn ok_prefix(
            rows: &[u32],
            col_cands: &[Vec<u32>],
            depth: usize,
        ) -> bool {
            // For each column, some candidate must match the first
            // `depth` bits laid down so far.
            for c in 0..N {
                let mut have = 0u32;
                for (r, &mask) in rows.iter().enumerate().take(depth) {
                    have |= ((mask >> c) & 1) << r;
                }
                let prefix_mask = (1u32 << depth) - 1;
                if !col_cands[c]
                    .iter()
                    .any(|&cand| cand & prefix_mask == have)
                {
                    return false;
                }
            }
            true
        }
        fn dfs(
            row_cands: &[Vec<u32>],
            col_cands: &[Vec<u32>],
            rows: &mut Vec<u32>,
            depth: usize,
        ) -> bool {
            if depth == N {
                return true;
            }
            for &cand in &row_cands[depth] {
                rows.push(cand);
                if ok_prefix(rows, col_cands, depth + 1)
                    && dfs(row_cands, col_cands, rows, depth + 1)
                {
                    return true;
                }
                rows.pop();
            }
            false
        }
        let mut rows = Vec::with_capacity(N);
        if !dfs(&row_cands, &col_cands, &mut rows, 0) {
            return None;
        }
        let mut grid = vec![false; N * N];
        for (r, mask) in rows.iter().enumerate() {
            for c in 0..N {
                grid[r * N + c] = mask >> c & 1 == 1;
            }
        }
        Some(grid)
    }

    fn write_obs(&self, obs: &mut [f32]) {
        // Layout: 25 grid cells, then 5x3 row clues, then 5x3 col clues
        // (zero-padded, normalised by N).
        for (o, &b) in obs.iter_mut().zip(&self.grid) {
            *o = b as u8 as f32;
        }
        let mut k = N * N;
        for clues in [&self.row_clues, &self.col_clues] {
            for clue in clues.iter() {
                for i in 0..MAX_RUNS {
                    obs[k] = clue.get(i).copied().unwrap_or(0) as f32 / N as f32;
                    k += 1;
                }
            }
        }
    }
}

impl Default for Nonogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for Nonogram {
    fn id(&self) -> String {
        "Puzzle/Nonogram-5x5".into()
    }

    fn observation_space(&self) -> Space {
        let dim = N * N + 2 * N * MAX_RUNS;
        Space::box1(vec![0.0; dim], vec![1.0; dim])
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: N * N }
    }

    fn obs_dim(&self) -> usize {
        N * N + 2 * N * MAX_RUNS
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x9fb21c651e98df25);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        // Draw a random target image, derive clues, blank the working
        // grid.  Clues from a real image are satisfiable by construction.
        loop {
            let target: Vec<bool> =
                (0..N * N).map(|_| self.rng.chance(self.fill_p)).collect();
            // Reject degenerate all-empty instances.
            if target.iter().any(|&b| b) {
                for r in 0..N {
                    self.row_clues[r] = clue_of(&target[r * N..(r + 1) * N]);
                }
                for c in 0..N {
                    let col: Vec<bool> = (0..N).map(|r| target[r * N + c]).collect();
                    self.col_clues[c] = clue_of(&col);
                }
                break;
            }
        }
        self.grid.fill(false);
        self.moves = 0;
        // An empty grid that already satisfies the clues would be a
        // zero-length episode; the all-empty rejection above prevents it.
        self.write_obs(obs);
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let cell = action.index();
        let before = self.satisfied_lines() as f32;
        self.grid[cell] = !self.grid[cell];
        self.moves += 1;
        let after = self.satisfied_lines() as f32;
        self.write_obs(obs);
        if self.solved() {
            Transition::terminal(10.0)
        } else {
            // Dense shaping: +- per newly satisfied/broken line.
            Transition::live(0.2 * (after - before) - 0.05)
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        fb.clear(0.05);
        let cw = fb.width() as f32 / N as f32;
        let ch = fb.height() as f32 / N as f32;
        for r in 0..N {
            for c in 0..N {
                if self.grid[r * N + c] {
                    raster::fill_rect(
                        fb,
                        (c as f32 * cw + 1.0) as i32,
                        (r as f32 * ch + 1.0) as i32,
                        ((c + 1) as f32 * cw - 1.0) as i32,
                        ((r + 1) as f32 * ch - 1.0) as i32,
                        0.9,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clue_of_computes_runs() {
        assert_eq!(clue_of(&[true, true, false, true, false]), vec![2, 1]);
        assert_eq!(clue_of(&[false; 5]), Vec::<u8>::new());
        assert_eq!(clue_of(&[true; 5]), vec![5]);
    }

    #[test]
    fn placements_enumerate_correctly() {
        // [2,1] in width 5: 2-run at 0/1/2 with 1-run after a gap.
        let p = placements(&[2, 1]);
        assert_eq!(p.len(), 3);
        // [5] has exactly one placement.
        assert_eq!(placements(&[5]), vec![0b11111]);
        // Impossible clue.
        assert!(placements(&[4, 2]).is_empty());
        // Empty clue = empty line.
        assert_eq!(placements(&[]), vec![0]);
    }

    #[test]
    fn solver_satisfies_generated_instances() {
        for seed in 0..10 {
            let mut env = Nonogram::new();
            env.seed(seed);
            let mut obs = vec![0.0; env.obs_dim()];
            env.reset_into(&mut obs);
            let solution = env.solve().expect("generated clues are satisfiable");
            let mut check = env.clone();
            check.grid = solution;
            assert!(check.solved(), "seed {seed}");
        }
    }

    #[test]
    fn env_episode_via_solver_toggles() {
        let mut env = Nonogram::new();
        env.seed(4);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset_into(&mut obs);
        let solution = env.solve().unwrap();
        let toggles: Vec<usize> = (0..N * N)
            .filter(|&i| solution[i] != env.grid()[i])
            .collect();
        assert!(!toggles.is_empty());
        let total = toggles.len();
        for (i, cell) in toggles.into_iter().enumerate() {
            let t = env.step_into(&Action::Discrete(cell), &mut obs);
            assert_eq!(t.done, i + 1 == total, "toggle {i}");
        }
    }

    #[test]
    fn shaping_rewards_line_completion() {
        let mut env = Nonogram::new();
        env.seed(4);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset_into(&mut obs);
        let solution = env.solve().unwrap();
        // Completing the first differing row eventually yields a positive
        // shaped step somewhere along the way.
        let mut saw_positive = false;
        for i in 0..N * N {
            if solution[i] != env.grid()[i] {
                let t = env.step_into(&Action::Discrete(i), &mut obs);
                if t.reward > 0.0 {
                    saw_positive = true;
                }
                if t.done {
                    break;
                }
            }
        }
        assert!(saw_positive);
    }

    #[test]
    fn obs_encodes_clues() {
        let mut env = Nonogram::new();
        env.seed(1);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset_into(&mut obs);
        // Grid cells all zero at reset; some clue slot must be nonzero.
        assert!(obs[..25].iter().all(|&v| v == 0.0));
        assert!(obs[25..].iter().any(|&v| v > 0.0));
    }

    #[test]
    fn different_seeds_different_instances() {
        let mut a = Nonogram::new();
        let mut b = Nonogram::new();
        a.seed(1);
        b.seed(2);
        let mut oa = vec![0.0; a.obs_dim()];
        let mut ob = vec![0.0; b.obs_dim()];
        a.reset_into(&mut oa);
        b.reset_into(&mut ob);
        assert_ne!(a.row_clues(), b.row_clues());
    }
}
