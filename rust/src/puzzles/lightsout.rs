//! Lights Out: pressing a cell toggles it and its orthogonal neighbours;
//! turn every light off.
//!
//! The solver is exact: Lights Out over GF(2) is a linear system
//! `A x = b` where `A` is the press-influence matrix — Gaussian
//! elimination yields a minimal certificate of solvability, which the
//! generator uses to emit only solvable instances (press-scrambling also
//! guarantees it; the solver double-checks).

use crate::core::env::{Env, Transition};
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::render::{raster, Framebuffer};

/// A Lights Out board of side `n`.
#[derive(Clone, Debug)]
pub struct LightsOut {
    n: usize,
    grid: Vec<bool>,
    moves: u32,
    rng: Pcg32,
    scramble_presses: u32,
}

impl LightsOut {
    pub fn new(n: usize) -> LightsOut {
        LightsOut {
            n,
            grid: vec![false; n * n],
            moves: 0,
            rng: Pcg32::new(0, 0x1f123bb5159a55e5),
            scramble_presses: (n * n) as u32,
        }
    }

    /// Curriculum knob: scramble with exactly `k` random presses (easier
    /// instances for small `k`).
    pub fn with_scramble(mut self, k: u32) -> LightsOut {
        self.scramble_presses = k;
        self
    }

    /// Construct the registered env variant.
    pub fn env(n: usize) -> LightsOut {
        LightsOut::new(n)
    }

    pub fn side(&self) -> usize {
        self.n
    }

    pub fn grid(&self) -> &[bool] {
        &self.grid
    }

    /// Press cell `(r, c)`: toggle it and its orthogonal neighbours.
    pub fn press(&mut self, r: usize, c: usize) {
        let n = self.n;
        let mut flip = |r: isize, c: isize| {
            if r >= 0 && r < n as isize && c >= 0 && c < n as isize {
                let i = r as usize * n + c as usize;
                self.grid[i] = !self.grid[i];
            }
        };
        let (r, c) = (r as isize, c as isize);
        flip(r, c);
        flip(r - 1, c);
        flip(r + 1, c);
        flip(r, c - 1);
        flip(r, c + 1);
    }

    /// All lights off?
    pub fn solved(&self) -> bool {
        self.grid.iter().all(|&b| !b)
    }

    /// Exact solver: returns the set of cells to press (each at most
    /// once; presses commute over GF(2)), or None if unsolvable.
    pub fn solve(&self) -> Option<Vec<usize>> {
        let n = self.n;
        let m = n * n;
        // Build the augmented influence matrix over GF(2), rows as bit
        // vectors in u64 chunks (m <= 64 supported for n <= 8: use Vec of
        // u128 to be safe up to n=11).
        assert!(m <= 128, "LightsOut solver supports n <= 11");
        let mut rows: Vec<(u128, bool)> = Vec::with_capacity(m);
        for cell in 0..m {
            let (r, c) = (cell / n, cell % n);
            let mut mask: u128 = 0;
            let mut add = |rr: isize, cc: isize| {
                if rr >= 0 && rr < n as isize && cc >= 0 && cc < n as isize {
                    mask |= 1u128 << (rr as usize * n + cc as usize);
                }
            };
            let (r, c) = (r as isize, c as isize);
            add(r, c);
            add(r - 1, c);
            add(r + 1, c);
            add(r, c - 1);
            add(r, c + 1);
            // Row `cell` of A^T == column of A; A is symmetric here.
            rows.push((mask, self.grid[cell]));
        }
        // Gaussian elimination.
        let mut pivot_of_col: Vec<Option<usize>> = vec![None; m];
        let mut row = 0;
        for col in 0..m {
            let Some(p) = (row..m).find(|&i| rows[i].0 >> col & 1 == 1) else {
                continue;
            };
            rows.swap(row, p);
            let (prow, pb) = rows[row];
            for (i, entry) in rows.iter_mut().enumerate() {
                if i != row && entry.0 >> col & 1 == 1 {
                    entry.0 ^= prow;
                    entry.1 ^= pb;
                }
            }
            pivot_of_col[col] = Some(row);
            row += 1;
            if row == m {
                break;
            }
        }
        // Inconsistent rows (0 = 1) mean unsolvable.
        if rows.iter().any(|&(mask, b)| mask == 0 && b) {
            return None;
        }
        let mut presses = Vec::new();
        for col in 0..m {
            if let Some(r) = pivot_of_col[col] {
                if rows[r].1 {
                    presses.push(col);
                }
            }
        }
        Some(presses)
    }
}

impl Env for LightsOut {
    fn id(&self) -> String {
        format!("Puzzle/LightsOut-{0}x{0}", self.n)
    }

    fn observation_space(&self) -> Space {
        Space::box1(vec![0.0; self.n * self.n], vec![1.0; self.n * self.n])
    }

    fn action_space(&self) -> Space {
        Space::Discrete { n: self.n * self.n }
    }

    fn obs_dim(&self) -> usize {
        self.n * self.n
    }

    fn seed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x1f123bb5159a55e5);
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        // Scramble by pressing random cells from solved — every instance
        // is solvable by construction.
        self.grid.fill(false);
        self.moves = 0;
        for _ in 0..self.scramble_presses {
            let cell = self.rng.below((self.n * self.n) as u32) as usize;
            self.press(cell / self.n, cell % self.n);
        }
        if self.solved() {
            // Pathological scramble landed back on solved; force one press.
            self.press(0, 0);
        }
        for (o, &b) in obs.iter_mut().zip(&self.grid) {
            *o = b as u8 as f32;
        }
    }

    fn step_into(&mut self, action: &Action, obs: &mut [f32]) -> Transition {
        let cell = action.index();
        self.press(cell / self.n, cell % self.n);
        self.moves += 1;
        for (o, &b) in obs.iter_mut().zip(&self.grid) {
            *o = b as u8 as f32;
        }
        if self.solved() {
            Transition::terminal(10.0)
        } else {
            Transition::live(-0.1)
        }
    }

    fn render(&self, fb: &mut Framebuffer) {
        fb.clear(0.05);
        let cw = fb.width() as f32 / self.n as f32;
        let ch = fb.height() as f32 / self.n as f32;
        for r in 0..self.n {
            for c in 0..self.n {
                if self.grid[r * self.n + c] {
                    raster::fill_rect(
                        fb,
                        (c as f32 * cw + 1.0) as i32,
                        (r as f32 * ch + 1.0) as i32,
                        ((c + 1) as f32 * cw - 1.0) as i32,
                        ((r + 1) as f32 * ch - 1.0) as i32,
                        0.9,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn press_toggles_plus_shape() {
        let mut p = LightsOut::new(5);
        p.press(2, 2);
        let on: Vec<usize> = (0..25).filter(|&i| p.grid[i]).collect();
        assert_eq!(on, vec![7, 11, 12, 13, 17]);
    }

    #[test]
    fn press_twice_is_identity() {
        let mut p = LightsOut::new(5);
        p.press(1, 3);
        p.press(1, 3);
        assert!(p.solved());
    }

    #[test]
    fn corner_press_clips() {
        let mut p = LightsOut::new(3);
        p.press(0, 0);
        let on: Vec<usize> = (0..9).filter(|&i| p.grid[i]).collect();
        assert_eq!(on, vec![0, 1, 3]);
    }

    #[test]
    fn solver_solves_scrambled_boards() {
        for seed in 0..10 {
            let mut p = LightsOut::new(5);
            p.seed(seed);
            let mut obs = vec![0.0; 25];
            p.reset_into(&mut obs);
            let presses = p.solve().expect("scrambles are solvable");
            for cell in presses {
                p.press(cell / 5, cell % 5);
            }
            assert!(p.solved(), "seed {seed}");
        }
    }

    #[test]
    fn solver_detects_unsolvable() {
        // On 5x5 a single lit corner cell is famously unsolvable.
        let mut p = LightsOut::new(5);
        p.grid[0] = true;
        assert!(p.solve().is_none());
    }

    #[test]
    fn env_episode_via_solver() {
        let mut env = LightsOut::new(3).with_scramble(4);
        env.seed(1);
        let mut obs = vec![0.0; 9];
        env.reset_into(&mut obs);
        let presses = env.solve().unwrap();
        let total = presses.len();
        for (i, cell) in presses.into_iter().enumerate() {
            let t = env.step_into(&Action::Discrete(cell), &mut obs);
            if i + 1 == total {
                assert!(t.done);
                assert_eq!(t.reward, 10.0);
            } else {
                assert!(!t.done);
                assert_eq!(t.reward, -0.1);
            }
        }
    }

    #[test]
    fn scramble_knob_controls_difficulty() {
        let mut easy = LightsOut::new(5).with_scramble(1);
        easy.seed(3);
        let mut obs = vec![0.0; 25];
        easy.reset_into(&mut obs);
        // One press lights at most 5 cells.
        assert!(easy.grid().iter().filter(|&&b| b).count() <= 5);
    }

    #[test]
    fn render_shows_lit_cells() {
        let mut env = LightsOut::new(5);
        env.seed(0);
        let mut obs = vec![0.0; 25];
        env.reset_into(&mut obs);
        let mut fb = Framebuffer::standard();
        env.render(&mut fb);
        assert!(fb.max() > 0.8);
    }
}
