//! Deterministic trajectory tapes: byte-stable record / replay of
//! batched workloads.
//!
//! A tape is the *portable witness* of a seeded run.  The determinism
//! contract (docs/ARCHITECTURE.md) says lane `i`'s trajectory is a pure
//! function of `(spec, base_seed + i, action stream)` — so a tape only
//! has to capture the header (spec, seed, lane layout) and, per batch,
//! the actions fed in and the transitions that came back.  Observations
//! are elided: replay re-derives them by re-executing, and the
//! transition comparison catches any divergence the observations would.
//!
//! ## On-disk format (version 1)
//!
//! ```text
//! file   = magic record*
//! magic  = "CAIRLTP" [version: u8]            (8 bytes)
//! record = [len: u32 LE] body [fnv1a32(body): u32 LE]
//! body   = [tag: u8] ...
//!   tag 1 HEADER: spec: str, wrap: str, lanes: u32, base_seed: u64,
//!                 steps_per_lane: u64,
//!                 [count: u32] (env_id: str, obs_dim: u32) x count
//!   tag 2 BATCH:  [count: u32] action x count,
//!                 [count: u32] transition x count
//!   tag 3 END:    batches: u64
//! ```
//!
//! `str` is `[len: u32] bytes` (UTF-8); `action` and `transition`
//! follow the shard wire spec's grammar (kind byte + payload; reward as
//! raw f32 bits, so equality is bit equality).  All integers are
//! little-endian.  The checksum constants match the shard protocol's
//! FNV-1a/32 ([`crate::shard::proto`]).
//!
//! Exactly one HEADER (first record) and one END (last record) are
//! legal; a missing END means the recording process died mid-run.
//! Decoding is **total**: truncation, checksum mismatch, hostile
//! counts or trailing bytes surface [`CairlError::Tape`], never a
//! panic and never an unbounded allocation.
//!
//! Byte stability: two runs of the same `(spec, wrap, lanes, seed,
//! steps)` produce byte-identical tapes **regardless of executor kind,
//! thread count, kernel mode or shard placement** — pinned by
//! `rust/tests/telemetry.rs` and the CI shard-smoke `cmp`.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::coordinator::pool::BatchedExecutor;
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::core::spaces::Action;

/// File magic: `CAIRLTP` + format version byte.
pub const TAPE_MAGIC: [u8; 8] = *b"CAIRLTP\x01";
/// Largest legal record payload; refused before allocation (a corrupt
/// length prefix must not become an OOM kill).
pub const MAX_RECORD: u32 = 1 << 26;

const TAG_HEADER: u8 = 1;
const TAG_BATCH: u8 = 2;
const TAG_END: u8 = 3;

fn terr(msg: impl Into<String>) -> CairlError {
    CairlError::Tape(msg.into())
}

/// FNV-1a/32 — the same checksum the shard wire protocol uses.
fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut hash: u32 = 0x811c_9dc5;
    for &b in bytes {
        hash ^= b as u32;
        hash = hash.wrapping_mul(0x0100_0193);
    }
    hash
}

// --- encoding helpers -------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_action(out: &mut Vec<u8>, a: &Action) {
    match a {
        Action::Discrete(i) => {
            out.push(0);
            put_u64(out, *i as u64);
        }
        Action::Continuous(v) => {
            out.push(1);
            put_u32(out, v.len() as u32);
            for &x in v {
                put_u32(out, x.to_bits());
            }
        }
    }
}

fn put_transition(out: &mut Vec<u8>, t: &Transition) {
    put_u32(out, t.reward.to_bits());
    out.push(u8::from(t.done) | (u8::from(t.truncated) << 1));
}

// --- bounds-checked decoding ------------------------------------------

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(terr(format!(
                "truncated record body: wanted {n} bytes, {} left",
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A count field validated against the bytes actually present, so a
    /// hostile count cannot drive a huge allocation.
    fn count(&mut self, min_elem: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem) > self.buf.len() - self.pos {
            return Err(terr(format!(
                "count {n} overruns record ({} bytes left)",
                self.buf.len() - self.pos
            )));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| terr("invalid UTF-8 in tape string"))
    }

    fn action(&mut self) -> Result<Action> {
        match self.u8()? {
            0 => Ok(Action::Discrete(self.u64()? as usize)),
            1 => {
                let n = self.count(4)?;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(f32::from_bits(self.u32()?));
                }
                Ok(Action::Continuous(v))
            }
            k => Err(terr(format!("unknown action kind {k}"))),
        }
    }

    fn transition(&mut self) -> Result<Transition> {
        let reward = f32::from_bits(self.u32()?);
        let flags = self.u8()?;
        if flags > 3 {
            return Err(terr(format!("invalid transition flags 0x{flags:02x}")));
        }
        Ok(Transition {
            reward,
            done: flags & 1 != 0,
            truncated: flags & 2 != 0,
        })
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(terr(format!(
                "{} trailing bytes after record body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// --- header -----------------------------------------------------------

/// Everything replay needs to rebuild a bit-identical executor, plus
/// per-lane summaries for divergence reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct TapeHeader {
    /// Registry spec the executor was built from (mixtures included).
    pub spec: String,
    /// Pool-level wrapper chain (`--wrap` grammar; empty = none).
    pub wrap: String,
    /// Number of lanes.
    pub lanes: usize,
    /// Base seed; lane `i` was seeded `base_seed + i`.
    pub base_seed: u64,
    /// Steps per lane the recorded workload ran.
    pub steps_per_lane: u64,
    /// Per-lane `(env_id, obs_dim)` as reported by
    /// [`BatchedExecutor::lane_specs`].
    pub lane_summaries: Vec<(String, u32)>,
}

impl TapeHeader {
    /// Assemble a header from a built executor and the workload knobs.
    pub fn for_executor(
        exec: &dyn BatchedExecutor,
        spec: &str,
        wrap: &str,
        base_seed: u64,
        steps_per_lane: u64,
    ) -> TapeHeader {
        TapeHeader {
            spec: spec.to_string(),
            wrap: wrap.to_string(),
            lanes: exec.num_lanes(),
            base_seed,
            steps_per_lane,
            lane_summaries: exec
                .lane_specs()
                .iter()
                .map(|s| (s.env_id.clone(), s.obs_dim as u32))
                .collect(),
        }
    }
}

// --- writer -----------------------------------------------------------

/// Streams a workload onto disk as a tape.  Created by
/// [`TapeWriter::create`]; [`TapeWriter::finish`] seals the tape with
/// the END record (a tape without one reads back as an error).
pub struct TapeWriter {
    w: BufWriter<File>,
    scratch: Vec<u8>,
    batches: u64,
    lanes: usize,
}

impl TapeWriter {
    /// Create `path` and write the magic + HEADER record.
    pub fn create(path: &Path, header: &TapeHeader) -> Result<TapeWriter> {
        let file = File::create(path)?;
        let mut writer = TapeWriter {
            w: BufWriter::new(file),
            scratch: Vec::with_capacity(4096),
            batches: 0,
            lanes: header.lanes,
        };
        writer.w.write_all(&TAPE_MAGIC)?;
        writer.scratch.clear();
        writer.scratch.push(TAG_HEADER);
        put_str(&mut writer.scratch, &header.spec);
        put_str(&mut writer.scratch, &header.wrap);
        put_u32(&mut writer.scratch, header.lanes as u32);
        put_u64(&mut writer.scratch, header.base_seed);
        put_u64(&mut writer.scratch, header.steps_per_lane);
        put_u32(&mut writer.scratch, header.lane_summaries.len() as u32);
        for (id, dim) in &header.lane_summaries {
            put_str(&mut writer.scratch, id);
            put_u32(&mut writer.scratch, *dim);
        }
        writer.flush_record()?;
        Ok(writer)
    }

    fn flush_record(&mut self) -> Result<()> {
        let body = &self.scratch;
        self.w.write_all(&(body.len() as u32).to_le_bytes())?;
        self.w.write_all(body)?;
        self.w.write_all(&fnv1a32(body).to_le_bytes())?;
        Ok(())
    }

    /// Append one batch: the actions fed to `step_into` and the
    /// transitions it returned.
    pub fn write_batch(&mut self, actions: &[Action], transitions: &[Transition]) -> Result<()> {
        debug_assert_eq!(actions.len(), self.lanes);
        debug_assert_eq!(transitions.len(), self.lanes);
        self.scratch.clear();
        self.scratch.push(TAG_BATCH);
        put_u32(&mut self.scratch, actions.len() as u32);
        for a in actions {
            put_action(&mut self.scratch, a);
        }
        put_u32(&mut self.scratch, transitions.len() as u32);
        for t in transitions {
            put_transition(&mut self.scratch, t);
        }
        self.batches += 1;
        self.flush_record()
    }

    /// Seal the tape (END record) and flush to disk.  Returns the
    /// number of batches written.
    pub fn finish(mut self) -> Result<u64> {
        self.scratch.clear();
        self.scratch.push(TAG_END);
        put_u64(&mut self.scratch, self.batches);
        self.flush_record()?;
        self.w.flush()?;
        Ok(self.batches)
    }
}

// --- reader -----------------------------------------------------------

/// One decoded BATCH record.
#[derive(Clone, Debug, PartialEq)]
pub struct TapeBatch {
    /// Per-lane actions fed to the executor.
    pub actions: Vec<Action>,
    /// Per-lane transitions the executor returned.
    pub transitions: Vec<Transition>,
}

/// Reads a tape back, validating every record's length and checksum.
pub struct TapeReader {
    r: BufReader<File>,
    header: TapeHeader,
    batches_read: u64,
    ended: bool,
}

impl TapeReader {
    /// Open `path`, validating the magic and the HEADER record.
    pub fn open(path: &Path) -> Result<TapeReader> {
        let file = File::open(path)?;
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)
            .map_err(|_| terr("file too short for tape magic"))?;
        if magic[..7] != TAPE_MAGIC[..7] {
            return Err(terr("not a CaiRL tape (bad magic)"));
        }
        if magic[7] != TAPE_MAGIC[7] {
            return Err(terr(format!(
                "unsupported tape version {} (this build reads {})",
                magic[7], TAPE_MAGIC[7]
            )));
        }
        let body = read_record(&mut r)?.ok_or_else(|| terr("tape ends before HEADER"))?;
        let mut cur = Cur { buf: &body, pos: 0 };
        if cur.u8()? != TAG_HEADER {
            return Err(terr("first tape record is not HEADER"));
        }
        let spec = cur.str()?;
        let wrap = cur.str()?;
        let lanes = cur.u32()? as usize;
        let base_seed = cur.u64()?;
        let steps_per_lane = cur.u64()?;
        let n = cur.count(5)?;
        let mut lane_summaries = Vec::with_capacity(n);
        for _ in 0..n {
            let id = cur.str()?;
            let dim = cur.u32()?;
            lane_summaries.push((id, dim));
        }
        cur.finish()?;
        if lanes == 0 || lane_summaries.len() != lanes {
            return Err(terr(format!(
                "header lane mismatch: {lanes} lanes, {} summaries",
                lane_summaries.len()
            )));
        }
        Ok(TapeReader {
            r,
            header: TapeHeader {
                spec,
                wrap,
                lanes,
                base_seed,
                steps_per_lane,
                lane_summaries,
            },
            batches_read: 0,
            ended: false,
        })
    }

    /// The decoded HEADER.
    pub fn header(&self) -> &TapeHeader {
        &self.header
    }

    /// Decode the next BATCH, or `None` after a valid END record.  EOF
    /// without an END is an error (the recording died mid-run).
    pub fn next_batch(&mut self) -> Result<Option<TapeBatch>> {
        if self.ended {
            return Ok(None);
        }
        let body = read_record(&mut self.r)?
            .ok_or_else(|| terr("tape truncated: EOF before END record"))?;
        let mut cur = Cur { buf: &body, pos: 0 };
        match cur.u8()? {
            TAG_BATCH => {
                let na = cur.count(2)?;
                let mut actions = Vec::with_capacity(na);
                for _ in 0..na {
                    actions.push(cur.action()?);
                }
                let nt = cur.count(5)?;
                let mut transitions = Vec::with_capacity(nt);
                for _ in 0..nt {
                    transitions.push(cur.transition()?);
                }
                cur.finish()?;
                if na != self.header.lanes || nt != self.header.lanes {
                    return Err(terr(format!(
                        "batch lane mismatch: {na} actions / {nt} transitions \
                         on a {}-lane tape",
                        self.header.lanes
                    )));
                }
                self.batches_read += 1;
                Ok(Some(TapeBatch { actions, transitions }))
            }
            TAG_END => {
                let declared = cur.u64()?;
                cur.finish()?;
                if declared != self.batches_read {
                    return Err(terr(format!(
                        "END declares {declared} batches, read {}",
                        self.batches_read
                    )));
                }
                self.ended = true;
                Ok(None)
            }
            TAG_HEADER => Err(terr("duplicate HEADER record")),
            t => Err(terr(format!("unknown tape record tag {t}"))),
        }
    }
}

/// Read one `[len] body [checksum]` record; `Ok(None)` at clean EOF
/// (the caller decides whether EOF is legal here).
fn read_record(r: &mut BufReader<File>) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_RECORD {
        return Err(terr(format!("implausible record length {len}")));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .map_err(|_| terr("tape truncated inside a record body"))?;
    let mut sum_buf = [0u8; 4];
    r.read_exact(&mut sum_buf)
        .map_err(|_| terr("tape truncated before a record checksum"))?;
    let expect = u32::from_le_bytes(sum_buf);
    let got = fnv1a32(&body);
    if got != expect {
        return Err(terr(format!(
            "record checksum mismatch (stored {expect:#010x}, computed {got:#010x})"
        )));
    }
    Ok(Some(body))
}

// --- replay -----------------------------------------------------------

/// The first point where a replay's transitions differ from the tape.
#[derive(Clone, Debug, PartialEq)]
pub struct TapeDivergence {
    /// 0-based batch index (== per-lane step index for lockstep runs).
    pub batch: u64,
    /// Lane whose transition diverged.
    pub lane: usize,
    /// What the tape recorded.
    pub expected: Transition,
    /// What the fresh executor produced.
    pub actual: Transition,
}

/// Result of [`replay_against`]: how much tape was replayed and the
/// first divergence, if any.
#[derive(Clone, Debug)]
pub struct ReplayOutcome {
    /// Batches re-executed (stops at the first divergence).
    pub batches: u64,
    /// Lane count of the tape.
    pub lanes: usize,
    /// `None` = byte-for-byte match.
    pub divergence: Option<TapeDivergence>,
}

/// Bit-exact transition equality (reward compared as raw f32 bits).
fn same_transition(a: &Transition, b: &Transition) -> bool {
    a.reward.to_bits() == b.reward.to_bits() && a.done == b.done && a.truncated == b.truncated
}

/// Re-execute `reader`'s tape against a freshly built executor (which
/// must match the header's spec/lanes/seed — see
/// [`TapeHeader`]) and compare every transition bit for bit.
///
/// Returns after the first divergent batch; a divergence is a
/// *finding*, not an error (`Err` is reserved for tape corruption and
/// executor/lane-shape mismatches).
pub fn replay_against(
    exec: &mut dyn BatchedExecutor,
    reader: &mut TapeReader,
) -> Result<ReplayOutcome> {
    let lanes = reader.header().lanes;
    if exec.num_lanes() != lanes {
        return Err(terr(format!(
            "executor has {} lanes, tape has {lanes}",
            exec.num_lanes()
        )));
    }
    let d = exec.obs_dim();
    let mut obs = vec![0.0f32; lanes * d];
    let mut transitions = vec![Transition::default(); lanes];
    exec.reset_into(&mut obs);
    let mut batches = 0u64;
    while let Some(batch) = reader.next_batch()? {
        exec.step_into(&batch.actions, &mut obs, &mut transitions);
        for (lane, (expected, actual)) in
            batch.transitions.iter().zip(transitions.iter()).enumerate()
        {
            if !same_transition(expected, actual) {
                return Ok(ReplayOutcome {
                    batches,
                    lanes,
                    divergence: Some(TapeDivergence {
                        batch: batches,
                        lane,
                        expected: *expected,
                        actual: *actual,
                    }),
                });
            }
        }
        batches += 1;
    }
    Ok(ReplayOutcome {
        batches,
        lanes,
        divergence: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cairl-tape-unit-{}-{tag}.tape", std::process::id()))
    }

    fn sample_header() -> TapeHeader {
        TapeHeader {
            spec: "CartPole-v1".to_string(),
            wrap: String::new(),
            lanes: 2,
            base_seed: 7,
            steps_per_lane: 3,
            lane_summaries: vec![
                ("CartPole-v1".to_string(), 4),
                ("CartPole-v1".to_string(), 4),
            ],
        }
    }

    #[test]
    fn roundtrip_header_and_batches() {
        let path = tmp_path("roundtrip");
        let header = sample_header();
        let mut w = TapeWriter::create(&path, &header).unwrap();
        let actions = vec![Action::Discrete(1), Action::Continuous(vec![0.5, -1.0])];
        let transitions = vec![
            Transition::live(1.0),
            Transition {
                reward: -0.25,
                done: true,
                truncated: true,
            },
        ];
        w.write_batch(&actions, &transitions).unwrap();
        assert_eq!(w.finish().unwrap(), 1);

        let mut r = TapeReader::open(&path).unwrap();
        assert_eq!(r.header(), &header);
        let batch = r.next_batch().unwrap().expect("one batch");
        assert_eq!(batch.actions, actions);
        assert_eq!(batch.transitions, transitions);
        assert!(r.next_batch().unwrap().is_none());
        // Past END stays None.
        assert!(r.next_batch().unwrap().is_none());
        let _ = std::fs::remove_file(&path);
    }

    /// Open and drain a tape end to end, surfacing the first error.
    fn drain(path: &Path) -> Result<()> {
        let mut r = TapeReader::open(path)?;
        while r.next_batch()?.is_some() {}
        Ok(())
    }

    #[test]
    fn corruption_is_an_error_never_a_panic() {
        let path = tmp_path("corrupt");
        let mut w = TapeWriter::create(&path, &sample_header()).unwrap();
        w.write_batch(
            &[Action::Discrete(0), Action::Discrete(1)],
            &[Transition::live(1.0), Transition::live(1.0)],
        )
        .unwrap();
        w.finish().unwrap();
        let clean = std::fs::read(&path).unwrap();
        assert!(drain(&path).is_ok(), "pristine tape must read clean");

        // Flip every byte in turn: every flip lands in the magic, a
        // length prefix, a checksummed body or a checksum — all are
        // detected.  The invariant under test: an error, never a panic.
        let dirty = tmp_path("corrupt-dirty");
        for i in 0..clean.len() {
            let mut bytes = clean.clone();
            bytes[i] ^= 0xff;
            std::fs::write(&dirty, &bytes).unwrap();
            assert!(drain(&dirty).is_err(), "byte {i} flip must be detected");
        }
        // Truncation at every length.
        for cut in 0..clean.len() {
            std::fs::write(&dirty, &clean[..cut]).unwrap();
            assert!(drain(&dirty).is_err(), "truncation at {cut} must be detected");
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&dirty);
    }

    #[test]
    fn unsealed_tape_reads_as_truncated() {
        // A writer dropped without finish() leaves no END record (the
        // recording process died mid-run); reading it back is an error.
        let path = tmp_path("unsealed");
        let w = TapeWriter::create(&path, &sample_header()).unwrap();
        drop(w); // BufWriter flushes magic + HEADER on drop
        let err = drain(&path).unwrap_err();
        assert!(matches!(err, CairlError::Tape(_)), "got {err}");
        let _ = std::fs::remove_file(&path);
    }
}
