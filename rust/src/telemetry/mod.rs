//! Fleet observability: the zero-allocation metrics core, distributed
//! batch tracing, and the deterministic trajectory tape.
//!
//! Three parts, all opt-in at the edges and free on the hot path:
//!
//! * [`metrics`] — a process-wide registry of counters, gauges and
//!   fixed-bucket histograms.  Handles are grabbed once at construction
//!   time (the only allocating step); recording is a handful of relaxed
//!   atomic operations with **zero steady-state allocation**, pinned by
//!   the counting-allocator suite in `rust/tests/alloc_free.rs`.  Every
//!   executor ([`VecEnv`](crate::coordinator::vec_env::VecEnv),
//!   [`EnvPool`](crate::coordinator::pool::EnvPool),
//!   [`AsyncEnvPool`](crate::coordinator::pool::AsyncEnvPool), the
//!   sharded pool), the shard client and the `cairl serve` daemon
//!   record into it.  Snapshots export as JSON (merged into
//!   `cairl serve --status`) or a Prometheus-style text dump
//!   (`cairl metrics`, `cairl run --metrics FILE`).  A process-wide
//!   enable gate ([`metrics::set_enabled`]) exists for A/B overhead
//!   measurement (`benches/ablation_dispatch.rs` asserts the cost).
//! * [`trace`] — per-thread ring buffers of POD span records covering
//!   every layer a batch crosses (dispatch, barrier/slot handoff,
//!   kernel, affine epilogue, shard encode → wire → server decode →
//!   server step → reassembly).  Disabled (the default) it costs one
//!   load + branch per site; `cairl run --trace FILE` exports Chrome
//!   `trace_event` JSON and `cairl trace --summarize FILE` prints the
//!   critical-path attribution table.  Shard protocol v6 carries a
//!   16-byte trace context so server-side spans stitch under the
//!   client's batch spans — one causally-ordered timeline per run.
//! * [`tape`] — byte-stable, length-prefixed, checksummed binary
//!   trajectory tapes.  `cairl run --record FILE` captures the header
//!   (registry spec, seed, lane layout) plus every batch's actions and
//!   transitions; observations are elided because the determinism
//!   contract (docs/ARCHITECTURE.md) re-derives them.  `cairl replay
//!   FILE` re-executes the tape against a fresh executor — any kind,
//!   any thread count, local or sharded — and reports byte-for-byte
//!   match or the first divergent (lane, step) with both transitions.
//!
//! The same tape recorded through any executor topology is
//! byte-identical, which is what makes a tape a *portable* fleet
//! artifact: record in production behind shards, bisect locally.

#![warn(missing_docs)]

pub mod metrics;
pub mod tape;
pub mod trace;

pub use metrics::{
    counter, enabled, gauge, histogram, prometheus_from_snapshot, render_prometheus,
    set_enabled, snapshot, Counter, ExecMetrics, Gauge, Histogram, LATENCY_BOUNDS_US,
};
pub use tape::{
    replay_against, ReplayOutcome, TapeBatch, TapeDivergence, TapeHeader, TapeReader,
    TapeWriter,
};
