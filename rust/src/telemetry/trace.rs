//! Distributed batch tracing: zero-allocation span recording, Chrome
//! `trace_event` export, and critical-path attribution.
//!
//! The metrics registry (PR 8) counts *how many* events happened; this
//! module answers **where a batch's microseconds went**.  Every layer a
//! batch crosses — executor dispatch, the sync barrier / async slot
//! handoff, each group's `step_batch`, the affine epilogue, shard frame
//! encode, the wire, the server's decode and step, reply reassembly —
//! records one POD [`SpanRecord`] into a per-thread fixed-capacity ring
//! buffer.  Design constraints mirror `metrics.rs`:
//!
//! 1. **Disabled = one relaxed load + branch.**  Tracing is opt-in
//!    (`cairl run --trace FILE`, or [`set_enabled`]); every record site
//!    checks [`enabled`] first and touches no clock otherwise.
//! 2. **Zero steady-state allocation.**  Rings are pre-sized at first
//!    use per thread; recording writes a 48-byte POD into a slot behind
//!    an uncontended mutex.  Overflow overwrites the oldest record and
//!    increments `cairl_trace_spans_dropped_total`.
//! 3. **Never perturbs determinism.**  Instrumentation only reads
//!    clocks and writes rings; episode-return logs are byte-identical
//!    with tracing on or off (pinned in `rust/tests/trace.rs`).
//!
//! Cross-shard stitching: shard protocol v6 carries a 16-byte
//! [`TraceCtx`] on every request frame, so a server can parent its
//! `decode`/`server_step` spans under the client's batch span, and
//! replies carry the measured server durations back so the client can
//! synthesize those spans into its own timeline even when the server is
//! a separate process (see `docs/shard-protocol.md` §3.3).
//!
//! Export: [`write_chrome_trace`] drains all rings into Chrome
//! `trace_event` JSON (loads in Perfetto / `chrome://tracing`, one
//! track per recording thread and per shard); [`read_chrome_trace`] +
//! [`summarize`] turn a trace file back into the attribution table
//! behind `cairl trace --summarize`.

use std::cell::{Cell, RefCell};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::core::error::{CairlError, Result};
use crate::core::json::{self, Value};
use crate::telemetry::metrics::{counter, Counter};

/// Process-wide trace gate.  **Disabled by default** — unlike metrics,
/// tracing is a diagnostic you switch on for a run.
static TRACE_ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span recording on or off process-wide.  While disabled every
/// record site is a single relaxed load plus an untaken branch.
pub fn set_enabled(on: bool) {
    TRACE_ENABLED.store(on, Ordering::Relaxed);
}

/// Whether span recording is currently enabled.
#[inline]
pub fn enabled() -> bool {
    TRACE_ENABLED.load(Ordering::Relaxed)
}

/// `shard` value for spans recorded by the local process rather than on
/// behalf of a numbered shard connection.
pub const SHARD_LOCAL: u32 = u32::MAX;

/// Monotonic nanoseconds since the first trace clock read in this
/// process.  All spans in one process share this epoch, which is what
/// makes their intervals comparable.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_nanos() as u64
}

static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Allocate a fresh nonzero span id (process-unique, monotone).
pub fn next_span_id() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// Allocate a fresh nonzero trace id.  One executor = one trace: every
/// batch it steps shares the id, which is what lets a whole run load as
/// a single causally-ordered timeline.
pub fn new_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// The 16-byte trace context carried on shard protocol v6 request
/// frames: which trace, and which client-side span to parent under.
/// All-zero (`TraceCtx::NONE`) means "untraced".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    /// Trace the batch belongs to (0 = untraced).
    pub trace_id: u64,
    /// Client-side parent span id (0 = root).
    pub span_id: u64,
}

impl TraceCtx {
    /// The untraced context (all zeroes on the wire).
    pub const NONE: TraceCtx = TraceCtx { trace_id: 0, span_id: 0 };

    /// Whether this context names no trace.
    pub fn is_none(&self) -> bool {
        self.trace_id == 0
    }
}

/// Span kinds, one per pipeline layer a batch crosses.  The `u8` repr
/// keeps [`SpanRecord`] POD.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Root span of one executor batch (`step_into` / pipelined
    /// submit→reply lifetime).
    Batch = 0,
    /// Executor plan/dispatch: command broadcast or mailbox sends.
    Dispatch = 1,
    /// Sync-pool barrier wait (`await_acks`).
    Queue = 2,
    /// One lane group's `step_batch` kernel call.
    Kernel = 3,
    /// The fused affine epilogue pass over a group's observations.
    Epilogue = 4,
    /// Async-pool slot handoff: ready-queue collect + slot copy-out.
    Slot = 5,
    /// Shard client frame encode + socket send.
    Encode = 6,
    /// Send-complete to reply-received on one shard connection.
    Wire = 7,
    /// Server-side frame decode (measured remotely, stitched locally).
    Decode = 8,
    /// Server-side executor step (measured remotely, stitched locally).
    ServerStep = 9,
    /// Reply scatter: tail-padded obs + transition copy-out.
    Reassemble = 10,
    /// Root span of one reset broadcast.
    Reset = 11,
}

/// Every kind, in attribution-table display order.
pub const SPAN_KINDS: [SpanKind; 12] = [
    SpanKind::Batch,
    SpanKind::Dispatch,
    SpanKind::Queue,
    SpanKind::Slot,
    SpanKind::Kernel,
    SpanKind::Epilogue,
    SpanKind::Encode,
    SpanKind::Wire,
    SpanKind::Decode,
    SpanKind::ServerStep,
    SpanKind::Reassemble,
    SpanKind::Reset,
];

impl SpanKind {
    /// Stable lowercase name (the Chrome event `name` and the
    /// attribution-table row label).
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanKind::Batch => "batch",
            SpanKind::Dispatch => "dispatch",
            SpanKind::Queue => "queue",
            SpanKind::Kernel => "kernel",
            SpanKind::Epilogue => "epilogue",
            SpanKind::Slot => "slot",
            SpanKind::Encode => "encode",
            SpanKind::Wire => "wire",
            SpanKind::Decode => "decode",
            SpanKind::ServerStep => "server_step",
            SpanKind::Reassemble => "reassemble",
            SpanKind::Reset => "reset",
        }
    }

    /// Inverse of [`SpanKind::as_str`].
    pub fn from_str(s: &str) -> Option<SpanKind> {
        SPAN_KINDS.iter().copied().find(|k| k.as_str() == s)
    }
}

/// One recorded span: plain old data, 48 bytes, no heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRecord {
    /// This span's id (nonzero).
    pub span_id: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
    /// Trace the span belongs to (nonzero for recorded spans).
    pub trace_id: u64,
    /// Start, nanoseconds on the [`now_ns`] clock.
    pub t_start_ns: u64,
    /// End, nanoseconds on the [`now_ns`] clock.
    pub t_end_ns: u64,
    /// Lane-group (or shard-plan) index the span covers.
    pub lane_group: u32,
    /// Shard connection index, or [`SHARD_LOCAL`].
    pub shard: u32,
    /// Which pipeline layer this span measures.
    pub kind: SpanKind,
}

/// Default per-thread ring capacity (spans).  48 bytes each, so the
/// default is ~768 KiB per recording thread.
pub const DEFAULT_RING_CAPACITY: usize = 16_384;

static RING_CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAPACITY);

/// Set the capacity used by rings created *after* this call (existing
/// rings keep their size).  Exists for overflow tests and
/// memory-constrained deployments; clamped to ≥ 2.
pub fn set_ring_capacity(cap: usize) {
    RING_CAPACITY.store(cap.max(2), Ordering::Relaxed);
}

/// Total spans overwritten by ring overflow, process-wide.  Mirrored
/// into the `cairl_trace_spans_dropped_total` metrics counter.
pub fn spans_dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

static DROPPED: AtomicU64 = AtomicU64::new(0);

struct Ring {
    cap: usize,
    buf: Vec<SpanRecord>,
    head: usize, // index of the oldest record once the ring is full
}

impl Ring {
    fn push(&mut self, rec: SpanRecord) -> bool {
        if self.buf.len() < self.cap {
            self.buf.push(rec);
            false
        } else {
            self.buf[self.head] = rec;
            self.head = (self.head + 1) % self.cap;
            true
        }
    }

    fn drain_ordered(&mut self) -> Vec<SpanRecord> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        self.buf.clear();
        self.head = 0;
        out
    }
}

fn ring_registry() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

struct RingSlot {
    tid: u32,
    ring: Arc<Mutex<Ring>>,
    dropped: Counter,
}

thread_local! {
    static RING: RefCell<Option<RingSlot>> = const { RefCell::new(None) };
    static CURRENT: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// Set this thread's implicit `(trace_id, parent span)` context.  Deep
/// layers with no ctx parameter of their own (the fused epilogue, a
/// worker's kernel call) parent their spans under [`current`].
pub fn set_current(trace_id: u64, span_id: u64) {
    CURRENT.with(|c| c.set((trace_id, span_id)));
}

/// This thread's implicit `(trace_id, parent span)` context; `(0, 0)`
/// when none is set.
pub fn current() -> (u64, u64) {
    CURRENT.with(|c| c.get())
}

/// Record one finished span into this thread's ring.  No-op while
/// tracing is disabled (one load + branch).  First call on a thread
/// allocates and registers its ring (the only allocating step).
#[inline]
pub fn record(rec: SpanRecord) {
    if !enabled() {
        return;
    }
    record_always(rec);
}

fn record_always(rec: SpanRecord) {
    RING.with(|slot| {
        let mut slot = slot.borrow_mut();
        let s = slot.get_or_insert_with(|| {
            let cap = RING_CAPACITY.load(Ordering::Relaxed);
            let ring = Arc::new(Mutex::new(Ring {
                cap,
                buf: Vec::with_capacity(cap),
                head: 0,
            }));
            let mut reg = ring_registry().lock().unwrap_or_else(|e| e.into_inner());
            let tid = reg.len() as u32;
            reg.push(Arc::clone(&ring));
            RingSlot {
                tid,
                ring,
                dropped: counter("cairl_trace_spans_dropped_total"),
            }
        });
        let overwrote = {
            let mut ring = s.ring.lock().unwrap_or_else(|e| e.into_inner());
            ring.push(rec)
        };
        if overwrote {
            DROPPED.fetch_add(1, Ordering::Relaxed);
            s.dropped.inc();
        }
    });
}

/// Run `f` inside a freshly-allocated child span of `(trace_id,
/// parent)`; the new span is this thread's [`current`] context for the
/// duration.  When tracing is disabled or `trace_id` is zero this is
/// just `f()` after one load + branch.
pub fn with_span<R>(
    kind: SpanKind,
    trace_id: u64,
    parent: u64,
    lane_group: u32,
    shard: u32,
    f: impl FnOnce() -> R,
) -> R {
    if !enabled() || trace_id == 0 {
        return f();
    }
    let span_id = next_span_id();
    let prev = current();
    set_current(trace_id, span_id);
    let t_start_ns = now_ns();
    let out = f();
    let t_end_ns = now_ns();
    set_current(prev.0, prev.1);
    record(SpanRecord {
        span_id,
        parent,
        trace_id,
        t_start_ns,
        t_end_ns,
        lane_group,
        shard,
        kind,
    });
    out
}

/// Drain every thread's ring, oldest-first per thread, returning
/// `(recording thread index, span)` pairs.  Rings stay registered and
/// reusable; only their contents move out.
pub fn drain() -> Vec<(u32, SpanRecord)> {
    let rings: Vec<Arc<Mutex<Ring>>> = {
        let reg = ring_registry().lock().unwrap_or_else(|e| e.into_inner());
        reg.iter().map(Arc::clone).collect()
    };
    let mut out = Vec::new();
    for (tid, ring) in rings.iter().enumerate() {
        let spans = ring.lock().unwrap_or_else(|e| e.into_inner()).drain_ordered();
        out.extend(spans.into_iter().map(|s| (tid as u32, s)));
    }
    out
}

// ---------------------------------------------------------------------------
// Chrome trace_event export
// ---------------------------------------------------------------------------

/// Render spans as Chrome `trace_event` JSON (complete `"X"` events,
/// microsecond timestamps).  Local spans land on `pid 0`, one `tid`
/// per recording thread; spans attributed to shard `s` (synthesized or
/// server-recorded) land on `pid s + 1` — one track per thread/shard.
/// `args` carries the raw record fields, including exact nanosecond
/// timestamps, so [`read_chrome_trace`] round-trips losslessly.
pub fn chrome_trace_json(spans: &[(u32, SpanRecord)]) -> String {
    let mut out = String::with_capacity(64 + spans.len() * 220);
    out.push_str("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[");
    let mut first = true;
    let mut pids: Vec<u32> = Vec::new();
    for (tid, s) in spans {
        let (pid, tid) = if s.shard == SHARD_LOCAL {
            (0u32, *tid + 1)
        } else {
            (s.shard + 1, 0u32)
        };
        if !pids.contains(&pid) {
            pids.push(pid);
        }
        if !first {
            out.push(',');
        }
        first = false;
        let ts = s.t_start_ns as f64 / 1000.0;
        let dur = s.t_end_ns.saturating_sub(s.t_start_ns) as f64 / 1000.0;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"cairl\",\"ph\":\"X\",\"ts\":{ts:.3},\
             \"dur\":{dur:.3},\"pid\":{pid},\"tid\":{tid},\"args\":{{\
             \"span_id\":{},\"parent\":{},\"trace_id\":{},\"kind\":\"{}\",\
             \"lane_group\":{},\"shard\":{},\"t_start_ns\":{},\"t_end_ns\":{}}}}}",
            s.kind.as_str(),
            s.span_id,
            s.parent,
            s.trace_id,
            s.kind.as_str(),
            s.lane_group,
            s.shard,
            s.t_start_ns,
            s.t_end_ns,
        ));
    }
    pids.sort_unstable();
    for pid in pids {
        let name = if pid == 0 {
            "client".to_string()
        } else {
            format!("shard {}", pid - 1)
        };
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }
    out.push_str("]}\n");
    out
}

/// Write `bytes` to `path` atomically: a sibling temp file is written
/// first, then renamed over the target, so readers (and a SIGTERM
/// drain) never observe a torn file.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)
}

/// Drain every ring and write the Chrome trace JSON to `path`
/// atomically.  Returns the number of spans written.
pub fn write_chrome_trace(path: &Path) -> std::io::Result<usize> {
    let spans = drain();
    write_atomic(path, chrome_trace_json(&spans).as_bytes())?;
    Ok(spans.len())
}

/// Parse a Chrome trace file written by [`write_chrome_trace`] back
/// into span records (metadata events are skipped; `args` carries the
/// exact nanosecond fields).
pub fn read_chrome_trace(path: &Path) -> Result<Vec<SpanRecord>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| CairlError::Config(format!("trace file {}: {e}", path.display())))?;
    let doc = json::parse(&text)?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_array())
        .ok_or_else(|| CairlError::Config("trace file has no traceEvents array".into()))?;
    let mut out = Vec::new();
    for ev in events {
        let Some(args) = ev.get("args") else { continue };
        let Some(t_start) = args.get("t_start_ns").and_then(Value::as_f64) else {
            continue; // metadata event
        };
        let kind_name = args.get("kind").and_then(Value::as_str).unwrap_or("");
        let Some(kind) = SpanKind::from_str(kind_name) else {
            return Err(CairlError::Config(format!("unknown span kind {kind_name:?}")));
        };
        let num = |k: &str| args.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        out.push(SpanRecord {
            span_id: num("span_id") as u64,
            parent: num("parent") as u64,
            trace_id: num("trace_id") as u64,
            t_start_ns: t_start as u64,
            t_end_ns: num("t_end_ns") as u64,
            lane_group: num("lane_group") as u32,
            shard: num("shard") as u32,
            kind,
        });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Critical-path attribution
// ---------------------------------------------------------------------------

fn percentile_us(sorted_ns: &[u64], q: f64) -> f64 {
    if sorted_ns.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ns.len() as f64 - 1.0) * q).round() as usize;
    sorted_ns[idx.min(sorted_ns.len() - 1)] as f64 / 1000.0
}

/// Render the critical-path attribution table for a set of spans: per
/// span kind, count, total time, share of total batch latency, and
/// p50/p95/p99 durations.  The `wire` row is net of the stitched
/// server-side `decode`/`server_step` time (those are sub-intervals of
/// the client's wire window), so the kinds tile without double
/// counting.  The closing coverage line reports how much of total
/// batch latency the direct child spans account for — the ≥95%
/// acceptance bar.
pub fn summarize(spans: &[SpanRecord]) -> String {
    let mut out = String::new();
    let batches: Vec<&SpanRecord> = spans.iter().filter(|s| s.kind == SpanKind::Batch).collect();
    if batches.is_empty() {
        out.push_str("no batch spans in trace\n");
        return out;
    }
    let total_batch_ns: u64 = batches
        .iter()
        .map(|s| s.t_end_ns.saturating_sub(s.t_start_ns))
        .sum();
    let total_by_kind = |kind: SpanKind| -> u64 {
        spans
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.t_end_ns.saturating_sub(s.t_start_ns))
            .sum()
    };
    out.push_str(&format!(
        "critical-path attribution ({} batches, {:.3} ms total batch latency)\n\n",
        batches.len(),
        total_batch_ns as f64 / 1e6
    ));
    out.push_str(&format!(
        "{:<12} {:>8} {:>12} {:>9} {:>10} {:>10} {:>10}\n",
        "kind", "count", "total ms", "% batch", "p50 us", "p95 us", "p99 us"
    ));
    for kind in SPAN_KINDS {
        let mut durs: Vec<u64> = spans
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.t_end_ns.saturating_sub(s.t_start_ns))
            .collect();
        if durs.is_empty() {
            continue;
        }
        durs.sort_unstable();
        let raw_total: u64 = durs.iter().sum();
        // Server-side time is a sub-interval of the client wire window:
        // report wire net of it so the rows tile.
        let attributed = if kind == SpanKind::Wire {
            raw_total.saturating_sub(
                total_by_kind(SpanKind::Decode) + total_by_kind(SpanKind::ServerStep),
            )
        } else {
            raw_total
        };
        let pct = if kind == SpanKind::Batch {
            100.0
        } else {
            100.0 * attributed as f64 / total_batch_ns.max(1) as f64
        };
        out.push_str(&format!(
            "{:<12} {:>8} {:>12.3} {:>9.1} {:>10.1} {:>10.1} {:>10.1}\n",
            kind.as_str(),
            durs.len(),
            attributed as f64 / 1e6,
            pct,
            percentile_us(&durs, 0.50),
            percentile_us(&durs, 0.95),
            percentile_us(&durs, 0.99),
        ));
    }
    let cov = coverage(spans);
    out.push_str(&format!(
        "\ncritical-path coverage: {:.1}% of batch latency attributed to child spans\n",
        cov * 100.0
    ));
    out
}

/// Fraction (0..=1) of total batch-span latency covered by the union of
/// each batch's direct child intervals, clipped to the batch window.
/// Interval union — not a sum — so overlapping children (worker kernels
/// inside the barrier wait, server spans inside the wire window) never
/// double count.
pub fn coverage(spans: &[SpanRecord]) -> f64 {
    let mut total: u64 = 0;
    let mut covered: u64 = 0;
    for b in spans.iter().filter(|s| s.kind == SpanKind::Batch) {
        let dur = b.t_end_ns.saturating_sub(b.t_start_ns);
        total += dur;
        let mut ivals: Vec<(u64, u64)> = spans
            .iter()
            .filter(|s| s.parent == b.span_id && s.trace_id == b.trace_id)
            .map(|s| (s.t_start_ns.max(b.t_start_ns), s.t_end_ns.min(b.t_end_ns)))
            .filter(|(a, z)| z > a)
            .collect();
        ivals.sort_unstable();
        let mut cur: Option<(u64, u64)> = None;
        for (a, z) in ivals {
            match cur {
                None => cur = Some((a, z)),
                Some((ca, cz)) if a <= cz => cur = Some((ca, cz.max(z))),
                Some((ca, cz)) => {
                    covered += cz - ca;
                    cur = Some((a, z));
                }
            }
        }
        if let Some((ca, cz)) = cur {
            covered += cz - ca;
        }
    }
    if total == 0 {
        0.0
    } else {
        covered as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The trace gate and rings are process-global; unit tests that
    /// enable tracing serialise and filter drained spans by their own
    /// trace id (concurrent sibling tests may record too).
    static GATE: Mutex<()> = Mutex::new(());

    fn rec(kind: SpanKind, tr: u64, span_id: u64, parent: u64, t0: u64, t1: u64) -> SpanRecord {
        SpanRecord {
            span_id,
            parent,
            trace_id: tr,
            t_start_ns: t0,
            t_end_ns: t1,
            lane_group: 0,
            shard: SHARD_LOCAL,
            kind,
        }
    }

    #[test]
    fn disabled_record_is_a_noop_and_with_span_still_runs() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        let tid = new_trace_id();
        record(rec(SpanKind::Batch, tid, next_span_id(), 0, 0, 10));
        let mut ran = false;
        with_span(SpanKind::Kernel, tid, 0, 0, SHARD_LOCAL, || ran = true);
        assert!(ran);
        let spans: Vec<_> = drain().into_iter().filter(|(_, s)| s.trace_id == tid).collect();
        assert!(spans.is_empty(), "disabled tracing must record nothing");
    }

    #[test]
    fn with_span_nests_and_restores_current() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        let tid = new_trace_id();
        let root = next_span_id();
        with_span(SpanKind::Kernel, tid, root, 3, SHARD_LOCAL, || {
            let (ct, cp) = current();
            assert_eq!(ct, tid);
            assert_ne!(cp, root, "current() should be the new child span");
            with_span(SpanKind::Epilogue, ct, cp, 3, SHARD_LOCAL, || {});
        });
        assert_eq!(current(), (0, 0), "context restored after the span");
        set_enabled(false);
        let spans: Vec<SpanRecord> = drain()
            .into_iter()
            .map(|(_, s)| s)
            .filter(|s| s.trace_id == tid)
            .collect();
        assert_eq!(spans.len(), 2);
        let kernel = spans.iter().find(|s| s.kind == SpanKind::Kernel).unwrap();
        let epi = spans.iter().find(|s| s.kind == SpanKind::Epilogue).unwrap();
        assert_eq!(kernel.parent, root);
        assert_eq!(epi.parent, kernel.span_id);
        assert!(kernel.t_start_ns <= epi.t_start_ns && epi.t_end_ns <= kernel.t_end_ns);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        // A fresh thread gets a fresh ring at the reduced capacity.
        set_ring_capacity(4);
        let tid = new_trace_id();
        let handle = std::thread::spawn(move || {
            set_enabled(true);
            for i in 0..6u64 {
                record(rec(SpanKind::Kernel, tid, 100 + i, 0, i, i + 1));
            }
            set_enabled(false);
        });
        handle.join().unwrap();
        set_ring_capacity(DEFAULT_RING_CAPACITY);
        let spans: Vec<SpanRecord> = drain()
            .into_iter()
            .map(|(_, s)| s)
            .filter(|s| s.trace_id == tid)
            .collect();
        let ids: Vec<u64> = spans.iter().map(|s| s.span_id).collect();
        assert_eq!(ids, vec![102, 103, 104, 105], "oldest two dropped, order kept");
        assert!(spans_dropped() >= 2);
    }

    #[test]
    fn chrome_json_round_trips_and_is_valid_json() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let tid = new_trace_id();
        let mut server = rec(SpanKind::ServerStep, tid, 3, 1, 2_000, 3_000);
        server.shard = 2;
        let spans = vec![
            (0u32, rec(SpanKind::Batch, tid, 1, 0, 1_000, 9_000)),
            (0u32, rec(SpanKind::Kernel, tid, 2, 1, 1_500, 7_000)),
            (1u32, server),
        ];
        let text = chrome_trace_json(&spans);
        let doc = json::parse(&text).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(Value::as_array).unwrap();
        // 3 spans + 2 process_name metadata records (pid 0 and pid 3).
        assert_eq!(events.len(), 5);

        let dir = std::env::temp_dir().join(format!("cairl_trace_rt_{tid}"));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_atomic(&path, text.as_bytes()).unwrap();
        let parsed = read_chrome_trace(&path).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0], spans[0].1);
        assert_eq!(parsed[2].shard, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summarize_attributes_and_covers() {
        let tid = 7;
        // batch [0,100]; dispatch [0,10]; queue [10,95]; kernel [20,90]
        // inside queue; reassemble [95,100].
        let spans = vec![
            rec(SpanKind::Batch, tid, 1, 0, 0, 100_000),
            rec(SpanKind::Dispatch, tid, 2, 1, 0, 10_000),
            rec(SpanKind::Queue, tid, 3, 1, 10_000, 95_000),
            rec(SpanKind::Kernel, tid, 4, 3, 20_000, 90_000),
            rec(SpanKind::Reassemble, tid, 5, 1, 95_000, 100_000),
        ];
        let table = summarize(&spans);
        assert!(table.contains("batch"), "{table}");
        assert!(table.contains("kernel"), "{table}");
        let cov = coverage(&spans);
        assert!((cov - 1.0).abs() < 1e-9, "children tile the batch: {cov}");
    }

    #[test]
    fn wire_row_is_net_of_server_time() {
        let tid = 9;
        let spans = vec![
            rec(SpanKind::Batch, tid, 1, 0, 0, 100_000),
            rec(SpanKind::Wire, tid, 2, 1, 0, 80_000),
            rec(SpanKind::Decode, tid, 3, 1, 10_000, 20_000),
            rec(SpanKind::ServerStep, tid, 4, 1, 20_000, 60_000),
        ];
        let table = summarize(&spans);
        // wire total 80us minus 10us decode minus 40us server_step = 30us
        // = 30% of the 100us batch.
        let wire_line = table.lines().find(|l| l.starts_with("wire")).unwrap();
        assert!(wire_line.contains("30.0"), "{wire_line}");
    }

    #[test]
    fn coverage_ignores_out_of_window_children() {
        let tid = 11;
        let spans = vec![
            rec(SpanKind::Batch, tid, 1, 0, 50_000, 100_000),
            rec(SpanKind::Kernel, tid, 2, 1, 0, 10_000), // entirely before
            rec(SpanKind::Kernel, tid, 3, 1, 50_000, 75_000),
        ];
        let cov = coverage(&spans);
        assert!((cov - 0.5).abs() < 1e-9, "{cov}");
    }
}
