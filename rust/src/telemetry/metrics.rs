//! Lock-free metrics: counters, gauges and fixed-bucket histograms in
//! one process-wide registry.
//!
//! Design constraints, in priority order:
//!
//! 1. **The record path allocates nothing and takes no lock.**  A
//!    handle ([`Counter`], [`Gauge`], [`Histogram`]) is an `Arc` around
//!    pre-sized atomics; `inc`/`set`/`record` are relaxed atomic
//!    operations plus (for histograms) a linear scan over a dozen fixed
//!    bounds.  Registration (`counter()`/`gauge()`/`histogram()`) is
//!    the cold path — it takes a mutex and may allocate, so callers
//!    grab handles **once at construction time**, never per step.
//! 2. **One registry per process.**  Two pools asking for the same
//!    metric name share one cell, so aggregate fleet counters come out
//!    right without any coordination between executors.
//! 3. **A/B measurable.**  [`set_enabled`] flips a process-wide atomic
//!    gate checked (one relaxed load) at every record site;
//!    `benches/ablation_dispatch.rs` measures on-vs-off and asserts the
//!    steady-state overhead stays under 2%.
//!
//! Naming follows the Prometheus convention:
//! `cairl_<area>_<what>[_total]{label="v"}` — the label block, when
//! present, is part of the registered name (the renderer splits it back
//! out).  The full metric inventory is documented in the README's
//! "Observability" section.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::core::json::Value;

/// Process-wide record gate (see [`set_enabled`]).  Enabled by default:
/// the whole point is that always-on costs nothing measurable.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Turn metric recording on or off process-wide.  Registration and
/// snapshots still work while disabled; only the hot-path `inc` /
/// `set` / `record` calls become no-ops.  Exists for the
/// `ablation_dispatch` overhead A/B, not as an operational switch.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether metric recording is currently enabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A monotonically increasing counter handle.  Clone freely — clones
/// share the underlying cell.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.  Zero-allocation, lock-free.
    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins gauge handle (queue depths, occupancy).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Set the gauge.  Zero-allocation, lock-free.
    #[inline]
    pub fn set(&self, v: i64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    /// Add a (possibly negative) delta.
    #[inline]
    pub fn add(&self, d: i64) {
        if enabled() {
            self.0.fetch_add(d, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Backing cell of a fixed-bucket histogram: `counts[i]` tallies values
/// `<= bounds[i]`, the final slot is the overflow (+Inf) bucket.
#[derive(Debug)]
pub struct HistogramCell {
    bounds: Vec<u64>,
    counts: Vec<AtomicU64>,
    sum: AtomicU64,
    total: AtomicU64,
}

/// A fixed-bucket histogram handle (latencies in integer units, e.g.
/// microseconds).  Bucket bounds are fixed at registration, so the
/// record path is a bounded linear scan — no allocation, no lock.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistogramCell>);

impl Histogram {
    /// Record one observation.  Zero-allocation, lock-free.
    #[inline]
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        let cell = &*self.0;
        let mut slot = cell.bounds.len();
        for (i, &b) in cell.bounds.iter().enumerate() {
            if v <= b {
                slot = i;
                break;
            }
        }
        cell.counts[slot].fetch_add(1, Ordering::Relaxed);
        cell.sum.fetch_add(v, Ordering::Relaxed);
        cell.total.fetch_add(1, Ordering::Relaxed);
    }

    /// Total number of recorded observations.
    pub fn count(&self) -> u64 {
        self.0.total.load(Ordering::Relaxed)
    }

    /// Sum of recorded observations.
    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }
}

/// Default latency bounds in microseconds: 50us .. 100ms, then +Inf.
pub const LATENCY_BOUNDS_US: [u64; 11] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000];

struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    histograms: Mutex<BTreeMap<String, Arc<HistogramCell>>>,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| Registry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        histograms: Mutex::new(BTreeMap::new()),
    })
}

/// Register (or look up) the counter `name`.  Cold path — call once at
/// construction, then record through the returned handle.
pub fn counter(name: &str) -> Counter {
    let mut map = registry().counters.lock().unwrap_or_else(|e| e.into_inner());
    let cell = map
        .entry(name.to_string())
        .or_insert_with(|| Arc::new(AtomicU64::new(0)));
    Counter(Arc::clone(cell))
}

/// Register (or look up) the gauge `name`.  Cold path.
pub fn gauge(name: &str) -> Gauge {
    let mut map = registry().gauges.lock().unwrap_or_else(|e| e.into_inner());
    let cell = map
        .entry(name.to_string())
        .or_insert_with(|| Arc::new(AtomicI64::new(0)));
    Gauge(Arc::clone(cell))
}

/// Register (or look up) the histogram `name` with the given ascending
/// bucket upper bounds (an overflow bucket is added implicitly).  A
/// second registration under the same name returns the existing cell
/// and ignores `bounds`.  Cold path.
pub fn histogram(name: &str, bounds: &[u64]) -> Histogram {
    let mut map = registry()
        .histograms
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    let cell = map.entry(name.to_string()).or_insert_with(|| {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Arc::new(HistogramCell {
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            total: AtomicU64::new(0),
        })
    });
    Histogram(Arc::clone(cell))
}

/// The per-executor counter bundle every `BatchedExecutor` records
/// into: lane-steps, batches and auto-reset episode boundaries, labeled
/// by executor kind (`vec` / `pool` / `pool-async` / `shard`).
#[derive(Clone, Debug)]
pub struct ExecMetrics {
    /// Lane-steps executed (`cairl_exec_steps_total`).
    pub steps: Counter,
    /// Batches stepped (`cairl_exec_batches_total`).
    pub batches: Counter,
    /// Episode ends observed, i.e. auto-resets
    /// (`cairl_exec_auto_resets_total`).
    pub auto_resets: Counter,
    /// Wall-clock per stepped batch in microseconds
    /// (`cairl_batch_latency_us`), derived from the same timestamps as
    /// the trace spans so metrics and traces can't disagree.
    pub latency: Histogram,
}

impl ExecMetrics {
    /// Handles for the executor kind label (cold path; call at pool
    /// construction).
    pub fn for_executor(kind: &str) -> ExecMetrics {
        ExecMetrics {
            steps: counter(&format!("cairl_exec_steps_total{{exec=\"{kind}\"}}")),
            batches: counter(&format!("cairl_exec_batches_total{{exec=\"{kind}\"}}")),
            auto_resets: counter(&format!(
                "cairl_exec_auto_resets_total{{exec=\"{kind}\"}}"
            )),
            latency: histogram(
                &format!("cairl_batch_latency_us{{exec=\"{kind}\"}}"),
                &LATENCY_BOUNDS_US,
            ),
        }
    }

    /// Record one stepped batch: `lanes` lane-steps and the episode
    /// ends among `ends`.  Zero-allocation.
    #[inline]
    pub fn record_batch(&self, lanes: usize, ends: usize) {
        self.batches.inc();
        self.steps.add(lanes as u64);
        if ends > 0 {
            self.auto_resets.add(ends as u64);
        }
    }

    /// [`ExecMetrics::record_batch`] plus the batch's wall-clock
    /// latency.  Executors pass the same start/end nanoseconds their
    /// trace spans carry.  Zero-allocation.
    #[inline]
    pub fn record_batch_timed(&self, lanes: usize, ends: usize, t_start_ns: u64, t_end_ns: u64) {
        self.record_batch(lanes, ends);
        self.latency.record(t_end_ns.saturating_sub(t_start_ns) / 1_000);
    }
}

/// Snapshot the whole registry as a JSON value:
///
/// ```json
/// {"counters": {"name": 12},
///  "gauges": {"name": -3},
///  "histograms": {"name": {"bounds": [...], "counts": [...],
///                          "sum": 98, "count": 7}}}
/// ```
///
/// `counts` has one more entry than `bounds` (the overflow bucket).
/// This is the document merged into `cairl serve --status` under the
/// `metrics` key, and the input [`prometheus_from_snapshot`] renders.
pub fn snapshot() -> Value {
    let reg = registry();
    let mut counters = BTreeMap::new();
    for (name, cell) in reg.counters.lock().unwrap_or_else(|e| e.into_inner()).iter() {
        counters.insert(name.clone(), Value::Num(cell.load(Ordering::Relaxed) as f64));
    }
    let mut gauges = BTreeMap::new();
    for (name, cell) in reg.gauges.lock().unwrap_or_else(|e| e.into_inner()).iter() {
        gauges.insert(name.clone(), Value::Num(cell.load(Ordering::Relaxed) as f64));
    }
    let mut histograms = BTreeMap::new();
    for (name, cell) in reg
        .histograms
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
    {
        let mut h = BTreeMap::new();
        h.insert(
            "bounds".to_string(),
            Value::Array(cell.bounds.iter().map(|&b| Value::Num(b as f64)).collect()),
        );
        h.insert(
            "counts".to_string(),
            Value::Array(
                cell.counts
                    .iter()
                    .map(|c| Value::Num(c.load(Ordering::Relaxed) as f64))
                    .collect(),
            ),
        );
        h.insert(
            "sum".to_string(),
            Value::Num(cell.sum.load(Ordering::Relaxed) as f64),
        );
        h.insert(
            "count".to_string(),
            Value::Num(cell.total.load(Ordering::Relaxed) as f64),
        );
        histograms.insert(name.clone(), Value::Object(h));
    }
    let mut doc = BTreeMap::new();
    doc.insert("counters".to_string(), Value::Object(counters));
    doc.insert("gauges".to_string(), Value::Object(gauges));
    doc.insert("histograms".to_string(), Value::Object(histograms));
    Value::Object(doc)
}

/// Render the live registry as Prometheus-style exposition text.
pub fn render_prometheus() -> String {
    prometheus_from_snapshot(&snapshot())
}

/// Split a registered name into (base, label-block-without-braces).
fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], name[i..].trim_start_matches('{').trim_end_matches('}')),
        None => (name, ""),
    }
}

/// Escape one label *value* for the Prometheus text format: `\` and
/// `"` get a backslash (values are stored raw in the registry; the
/// renderer escapes at exposition time).
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            c => out.push(c),
        }
    }
    out
}

/// Escape every value in a `k="v",k2="v2"` label block.  Values are
/// stored raw (env ids may contain `"` or `\`), so a value ends at a
/// quote followed by end-of-block or by `,key="` — the only shape the
/// registry produces.  A block that doesn't parse is passed through
/// unchanged rather than dropped.
fn escape_label_block(block: &str) -> String {
    fn value_end(bytes: &[u8], mut i: usize) -> Option<usize> {
        while i < bytes.len() {
            if bytes[i] == b'"' {
                let rest = &bytes[i + 1..];
                if rest.is_empty() {
                    return Some(i);
                }
                if rest[0] == b',' {
                    // `,key="` starts the next pair?
                    let mut j = 1;
                    while j < rest.len() && (rest[j].is_ascii_alphanumeric() || rest[j] == b'_') {
                        j += 1;
                    }
                    if j > 1 && rest.get(j) == Some(&b'=') && rest.get(j + 1) == Some(&b'"') {
                        return Some(i);
                    }
                }
            }
            i += 1;
        }
        None
    }
    let bytes = block.as_bytes();
    let mut out = String::with_capacity(block.len());
    let mut i = 0;
    while i < bytes.len() {
        // key="
        let Some(eq) = block[i..].find("=\"").map(|p| i + p) else {
            return block.to_string();
        };
        out.push_str(&block[i..eq]);
        out.push_str("=\"");
        let vstart = eq + 2;
        let Some(vend) = value_end(bytes, vstart) else {
            return block.to_string();
        };
        out.push_str(&escape_label_value(&block[vstart..vend]));
        out.push('"');
        i = vend + 1;
        if i < bytes.len() {
            // the `,` separator before the next pair
            out.push(',');
            i += 1;
        }
    }
    out
}

fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render a [`snapshot`]-shaped JSON document (local or fetched from a
/// daemon's `--status` report) as Prometheus-style exposition text.
/// Histogram buckets come out cumulative with an explicit `+Inf`
/// bucket, plus `_sum` and `_count` series, per the text format.
pub fn prometheus_from_snapshot(snap: &Value) -> String {
    let mut out = String::new();
    let mut typed: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    let mut type_line = |out: &mut String, base: &str, kind: &str| {
        if typed.insert(base.to_string()) {
            out.push_str(&format!("# TYPE {base} {kind}\n"));
        }
    };
    for (section, kind) in [("counters", "counter"), ("gauges", "gauge")] {
        if let Some(map) = snap.get(section).and_then(|v| v.as_object()) {
            for (name, v) in map {
                let (base, labels) = split_labels(name);
                let labels = escape_label_block(labels);
                type_line(&mut out, base, kind);
                let value = fmt_num(v.as_f64().unwrap_or(0.0));
                if labels.is_empty() {
                    out.push_str(&format!("{base} {value}\n"));
                } else {
                    out.push_str(&format!("{base}{{{labels}}} {value}\n"));
                }
            }
        }
    }
    if let Some(map) = snap.get("histograms").and_then(|v| v.as_object()) {
        for (name, h) in map {
            let (base, labels) = split_labels(name);
            let labels = escape_label_block(labels);
            type_line(&mut out, base, "histogram");
            let bounds: Vec<f64> = h
                .get("bounds")
                .and_then(|v| v.as_array())
                .map(|xs| xs.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default();
            let counts: Vec<f64> = h
                .get("counts")
                .and_then(|v| v.as_array())
                .map(|xs| xs.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default();
            let mut cumulative = 0.0;
            for (i, &c) in counts.iter().enumerate() {
                cumulative += c;
                let le = match bounds.get(i) {
                    Some(b) => fmt_num(*b),
                    None => "+Inf".to_string(),
                };
                let le_label = if labels.is_empty() {
                    format!("le=\"{le}\"")
                } else {
                    format!("{labels},le=\"{le}\"")
                };
                out.push_str(&format!(
                    "{base}_bucket{{{le_label}}} {}\n",
                    fmt_num(cumulative)
                ));
            }
            let tail = |suffix: &str, v: f64, out: &mut String| {
                if labels.is_empty() {
                    out.push_str(&format!("{base}{suffix} {}\n", fmt_num(v)));
                } else {
                    out.push_str(&format!("{base}{suffix}{{{labels}}} {}\n", fmt_num(v)));
                }
            };
            tail("_sum", h.get("sum").and_then(|v| v.as_f64()).unwrap_or(0.0), &mut out);
            tail(
                "_count",
                h.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0),
                &mut out,
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry (and the enable gate) are process-global; tests
    /// that record or flip the gate serialise so a concurrent sibling
    /// can't observe a half-disabled window.
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn counter_and_gauge_roundtrip() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let c = counter("test_metrics_counter_total");
        let before = c.get();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), before + 5);
        // Same name, same cell.
        assert_eq!(counter("test_metrics_counter_total").get(), before + 5);

        let g = gauge("test_metrics_gauge");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let h = histogram("test_metrics_hist", &[10, 100]);
        let base = h.count();
        h.record(3); // bucket 0
        h.record(100); // bucket 1 (le is inclusive)
        h.record(5_000); // overflow
        assert_eq!(h.count(), base + 3);
        assert!(h.sum() >= 5_103);
    }

    #[test]
    fn disabled_gate_drops_records() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let c = counter("test_metrics_gated_total");
        let before = c.get();
        set_enabled(false);
        c.inc();
        set_enabled(true);
        c.inc();
        assert_eq!(c.get(), before + 1);
    }

    #[test]
    fn snapshot_shape_and_prometheus_render() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        counter("test_snap_counter_total").add(2);
        gauge("test_snap_gauge{lane=\"0\"}").set(-1);
        histogram("test_snap_hist", &[1, 2]).record(9);
        let snap = snapshot();
        assert!(snap.get("counters").is_some());
        assert!(snap.get("gauges").is_some());
        let h = snap
            .path(&["histograms", "test_snap_hist"])
            .expect("histogram present");
        assert_eq!(h.get("bounds").and_then(|v| v.as_array()).unwrap().len(), 2);
        assert_eq!(h.get("counts").and_then(|v| v.as_array()).unwrap().len(), 3);

        let text = prometheus_from_snapshot(&snap);
        assert!(text.contains("# TYPE test_snap_counter_total counter"));
        assert!(text.contains("test_snap_gauge{lane=\"0\"} -1"));
        assert!(text.contains("test_snap_hist_bucket{le=\"+Inf\"}"));
        assert!(text.contains("test_snap_hist_count"));
        // JSON round-trip: rendering the parsed snapshot matches.
        let reparsed =
            crate::core::json::parse(&snap.render()).expect("snapshot renders valid JSON");
        assert_eq!(prometheus_from_snapshot(&reparsed), text);
    }

    #[test]
    fn exec_metrics_record_batch() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let m = ExecMetrics::for_executor("test-kind");
        let s0 = m.steps.get();
        m.record_batch(8, 2);
        m.record_batch(8, 0);
        assert_eq!(m.steps.get(), s0 + 16);
        assert!(m.batches.get() >= 2);
        assert!(m.auto_resets.get() >= 2);
    }

    #[test]
    fn exec_metrics_record_latency_from_span_timestamps() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let m = ExecMetrics::for_executor("test-latency");
        let c0 = m.latency.count();
        m.record_batch_timed(8, 0, 1_000_000, 4_500_000); // 3.5 ms
        assert_eq!(m.latency.count(), c0 + 1);
        assert!(m.latency.sum() >= 3_500);
        let text = render_prometheus();
        assert!(
            text.contains("cairl_batch_latency_us_bucket{exec=\"test-latency\",le=\"5000\"}"),
            "{text}"
        );
    }

    #[test]
    fn prometheus_escapes_hostile_label_values() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        counter("test_escape_total{env=\"My\\\"Env\\chaos\",lane=\"0\"}").add(1);
        histogram("test_escape_hist{env=\"a\\\"b\"}", &[1]).record(1);
        let text = render_prometheus();
        assert!(
            text.contains("test_escape_total{env=\"My\\\\\\\"Env\\\\chaos\",lane=\"0\"} "),
            "{text}"
        );
        assert!(
            text.contains("test_escape_hist_bucket{env=\"a\\\\\\\"b\",le=\"1\"}"),
            "{text}"
        );
        // Benign labels render byte-identically to before.
        assert_eq!(escape_label_block("exec=\"pool\""), "exec=\"pool\"");
        assert_eq!(escape_label_block("a=\"x\",b=\"y\""), "a=\"x\",b=\"y\"");
    }
}
