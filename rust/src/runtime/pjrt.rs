//! PJRT client wrapper: HLO text -> compiled executable -> typed calls.
//!
//! Follows the verified pattern from `/opt/xla-example/load_hlo.rs`:
//! `HloModuleProto::from_text_file` (the text parser reassigns the
//! 64-bit instruction ids jax >= 0.5 emits, which this XLA build rejects
//! in proto form) -> `XlaComputation::from_proto` -> `client.compile`.
//! All artifacts are lowered with `return_tuple=True`, so outputs arrive
//! as one tuple literal and are decomposed here.

use std::collections::HashMap;
use std::path::Path;

use crate::core::error::{CairlError, Result};
use crate::runtime::artifacts::{ArtifactMeta, Manifest};

fn rt<E: std::fmt::Display>(ctx: &str) -> impl FnOnce(E) -> CairlError + '_ {
    move |e| CairlError::Runtime(format!("{ctx}: {e}"))
}

/// One compiled artifact plus its manifest signature.
pub struct Module {
    pub name: String,
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Module {
    /// Execute with positional literal inputs; returns the decomposed
    /// output tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(CairlError::Runtime(format!(
                "{}: expected {} operands, got {}",
                self.name,
                self.meta.inputs.len(),
                inputs.len()
            )));
        }
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(rt(&self.name))?[0][0]
            .to_literal_sync()
            .map_err(rt(&self.name))?;
        let outputs = result.to_tuple().map_err(rt(&self.name))?;
        if outputs.len() != self.meta.outputs.len() {
            return Err(CairlError::Runtime(format!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.meta.outputs.len(),
                outputs.len()
            )));
        }
        Ok(outputs)
    }

    /// Execute with device-resident buffer inputs, returning the raw
    /// output buffers (untupled when the PJRT client untuples, else one
    /// tuple buffer — callers check `len()`).
    ///
    /// §Perf fast path: chaining one call's outputs into the next call's
    /// inputs keeps state device-resident and skips the host round-trip
    /// of `execute` + `to_literal_sync`.
    pub fn execute_buffers(
        &self,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut outs = self
            .exe
            .execute_b::<xla::PjRtBuffer>(inputs)
            .map_err(rt(&self.name))?;
        Ok(outs.swap_remove(0))
    }

    /// [`Module::execute_buffers`] over borrowed buffers (lets callers
    /// alias one buffer into several operand slots, e.g. online == target
    /// right after a sync).
    pub fn execute_buffers_ref(
        &self,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(rt(&self.name))?;
        Ok(outs.swap_remove(0))
    }

    /// Execute and read every output back as `Vec<f32>`.
    pub fn execute_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.execute(inputs)?
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(rt(&self.name)))
            .collect()
    }
}

/// The PJRT CPU runtime: client + compiled-module cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    modules: HashMap<String, Module>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(rt("PjRtClient::cpu"))?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime {
            client,
            manifest,
            modules: HashMap::new(),
        })
    }

    /// Create from the default artifact directory.
    pub fn from_default_artifacts() -> Result<Runtime> {
        let dir = crate::runtime::artifacts::default_artifact_dir();
        Self::new(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The underlying PJRT client (device-buffer creation).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Upload an f32 tensor to the device.
    pub fn to_device(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(rt("to_device"))
    }

    /// Upload an i32 tensor to the device.
    pub fn to_device_i32(
        &self,
        data: &[i32],
        shape: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(rt("to_device_i32"))
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<&Module> {
        if !self.modules.contains_key(name) {
            let meta = self.manifest.artifact(name)?.clone();
            let path = self.manifest.artifact_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(rt(&format!("parse {}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(rt(&format!("compile {name}")))?;
            self.modules.insert(
                name.to_string(),
                Module {
                    name: name.to_string(),
                    meta,
                    exe,
                },
            );
        }
        Ok(&self.modules[name])
    }
}

/// Build an f32 literal of the given logical shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    assert_eq!(data.len(), shape.iter().product::<usize>());
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(rt("reshape"))
}

/// Build a scalar f32 literal.
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Build a 1-D i32 literal.
pub fn literal_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_artifact_dir;

    // PJRT clients are process-heavy; integration tests
    // (rust/tests/runtime_integration.rs) cover execution extensively.
    // Here: construction, caching and operand validation.

    #[test]
    fn literal_builders_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let s = scalar_f32(7.5);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
        let i = literal_i32(&[1, 2, 3]);
        assert_eq!(i.element_count(), 3);
    }

    #[test]
    fn runtime_loads_and_caches_modules() {
        let Ok(mut rt) = Runtime::new(&default_artifact_dir()) else {
            eprintln!("SKIP runtime_loads_and_caches_modules: PJRT unavailable");
            return;
        };
        rt.load("dqn_act_cartpole").unwrap();
        // Second load must hit the cache (same pointer name, no error).
        let m = rt.load("dqn_act_cartpole").unwrap();
        assert_eq!(m.meta.inputs.len(), 7);
        assert!(rt.load("no_such_artifact").is_err());
    }

    #[test]
    fn execute_validates_operand_count() {
        let Ok(mut rt) = Runtime::new(&default_artifact_dir()) else {
            eprintln!("SKIP execute_validates_operand_count: PJRT unavailable");
            return;
        };
        let m = rt.load("dqn_act_cartpole").unwrap();
        let err = match m.execute(&[scalar_f32(0.0)]) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("operand-count mismatch must fail"),
        };
        assert!(err.contains("expected 7 operands"), "{err}");
    }

    #[test]
    fn runtime_construction_error_is_actionable() {
        // Whichever leg is missing (PJRT client or artifacts), the error
        // must point at it rather than panicking.
        match Runtime::new(&default_artifact_dir()) {
            Ok(_) => {}
            Err(e) => {
                let text = e.to_string();
                assert!(
                    text.contains("PJRT") || text.contains("make artifacts"),
                    "unhelpful runtime error: {text}"
                );
            }
        }
    }
}
