//! The DQN executor: Table-I network state held in Rust, compute done by
//! the AOT artifacts (fused Pallas forward inside).
//!
//! Owns the online/target parameters, Adam state and step counter as
//! host vectors; `act` and `train_step` marshal them into PJRT literals,
//! execute the artifact, and write the updated state back.  Target-network
//! sync is a host-side copy — no artifact needed.

use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::runtime::pjrt::{literal_f32, literal_i32, scalar_f32, Runtime};

/// One transition batch in struct-of-arrays layout (matches the train
/// artifact's `s, a, r, s2, done` operands).
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub s: Vec<f32>,
    pub a: Vec<i32>,
    pub r: Vec<f32>,
    pub s2: Vec<f32>,
    pub done: Vec<f32>,
}

/// The six parameter tensors in artifact order (w1 b1 w2 b2 w3 b3).
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub tensors: Vec<Vec<f32>>,
    pub shapes: Vec<Vec<usize>>,
}

impl ParamSet {
    fn zeros_like(&self) -> ParamSet {
        ParamSet {
            tensors: self.tensors.iter().map(|t| vec![0.0; t.len()]).collect(),
            shapes: self.shapes.clone(),
        }
    }
}

/// DQN bound to one environment spec's artifacts.
pub struct DqnExecutor {
    env_name: String,
    pub obs_dim: usize,
    pub n_actions: usize,
    pub batch_size: usize,
    params: ParamSet,
    target: ParamSet,
    adam_m: ParamSet,
    adam_v: ParamSet,
    t: f32,
    /// Train steps executed.
    pub steps: u64,
}

impl DqnExecutor {
    /// Initialise with He-uniform weights (same scheme as
    /// `model.init_params`) from a seed, reading the network spec from
    /// the runtime's artifact manifest.
    pub fn new(rt: &Runtime, env_name: &str, seed: u64) -> Result<DqnExecutor> {
        let spec = rt
            .manifest()
            .env_specs
            .get(env_name)
            .ok_or_else(|| {
                CairlError::Runtime(format!("no env spec {env_name:?} in manifest"))
            })?
            .clone();
        let hidden = rt.manifest().hyperparameters.hidden;
        let batch_size = rt.manifest().hyperparameters.batch;
        Ok(Self::from_spec(
            env_name,
            spec.obs_dim,
            spec.n_actions,
            hidden,
            batch_size,
            seed,
        ))
    }

    /// Initialise from explicit network dimensions, without a [`Runtime`]
    /// or artifacts.  The native host paths ([`Self::q_values_native`],
    /// [`Self::act_greedy_batch_native`]) are fully functional on such an
    /// executor; the PJRT paths additionally need a runtime whose
    /// manifest carries matching `dqn_*_{env_name}` artifacts.  Batched
    /// greedy evaluation over a
    /// [`BatchedExecutor`](crate::coordinator::pool::BatchedExecutor)
    /// builds on this (see
    /// [`crate::agents::dqn::evaluate_greedy_batched`]).
    pub fn from_spec(
        env_name: &str,
        obs_dim: usize,
        n_actions: usize,
        hidden: usize,
        batch_size: usize,
        seed: u64,
    ) -> DqnExecutor {
        let shapes: Vec<Vec<usize>> = vec![
            vec![obs_dim, hidden],
            vec![hidden],
            vec![hidden, hidden],
            vec![hidden],
            vec![hidden, n_actions],
            vec![n_actions],
        ];
        let mut rng = Pcg32::new(seed, 0x0dd4b2b0b2b7e0d5);
        let tensors = shapes
            .iter()
            .map(|sh| {
                let n: usize = sh.iter().product();
                if sh.len() == 2 {
                    let bound = (6.0 / sh[0] as f32).sqrt();
                    (0..n).map(|_| rng.uniform(-bound, bound)).collect()
                } else {
                    vec![0.0; n]
                }
            })
            .collect();
        let params = ParamSet { tensors, shapes };
        let target = params.clone();
        let adam_m = params.zeros_like();
        let adam_v = params.zeros_like();
        DqnExecutor {
            env_name: env_name.to_string(),
            obs_dim,
            n_actions,
            batch_size,
            params,
            target,
            adam_m,
            adam_v,
            t: 0.0,
            steps: 0,
        }
    }

    /// Replace the online parameters (e.g. with the manifest's seeded
    /// init for bit-reproducible golden tests).
    pub fn set_params(&mut self, tensors: Vec<Vec<f32>>) {
        assert_eq!(tensors.len(), 6);
        for (t, sh) in tensors.iter().zip(&self.params.shapes) {
            assert_eq!(t.len(), sh.iter().product::<usize>());
        }
        self.params.tensors = tensors.clone();
        self.target.tensors = tensors;
    }

    /// Current online parameters (flattened, artifact order).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params.tensors
    }

    /// Copy online -> target (the DQN target-network sync).
    pub fn sync_target(&mut self) {
        self.target.tensors.clone_from(&self.params.tensors);
    }

    fn param_literals(&self, set: &ParamSet) -> Result<Vec<xla::Literal>> {
        set.tensors
            .iter()
            .zip(&set.shapes)
            .map(|(t, sh)| literal_f32(t, sh))
            .collect()
    }

    /// The native forward pass into caller-owned buffers (`h1`/`h2` are
    /// hidden-layer scratch, reused across rows by the batched paths so
    /// the hot loop is allocation-free).
    fn forward_into(&self, obs: &[f32], h1: &mut [f32], h2: &mut [f32], q: &mut [f32]) {
        assert_eq!(obs.len(), self.obs_dim);
        let p = &self.params.tensors;
        let hidden = self.params.shapes[0][1];
        let elu = |x: f32| if x > 0.0 { x } else { x.exp() - 1.0 };
        // h1 = elu(obs @ w1 + b1)
        for (j, h) in h1.iter_mut().enumerate() {
            let mut acc = p[1][j];
            for (i, &o) in obs.iter().enumerate() {
                acc += o * p[0][i * hidden + j];
            }
            *h = elu(acc);
        }
        // h2 = elu(h1 @ w2 + b2)
        for (j, h) in h2.iter_mut().enumerate() {
            let mut acc = p[3][j];
            for (i, &x) in h1.iter().enumerate() {
                acc += x * p[2][i * hidden + j];
            }
            *h = elu(acc);
        }
        // q = h2 @ w3 + b3
        for (j, qv) in q.iter_mut().enumerate() {
            let mut acc = p[5][j];
            for (i, &x) in h2.iter().enumerate() {
                acc += x * p[4][i * self.n_actions + j];
            }
            *qv = acc;
        }
    }

    /// Hidden width (scratch-buffer size for the native forward).
    fn hidden_dim(&self) -> usize {
        self.params.shapes[0][1]
    }

    /// Q-values for a single observation computed natively on the host.
    ///
    /// §Perf fast path: the online parameters already live host-side
    /// (they are round-tripped by every train step), and a 4->32->32->|A|
    /// forward is ~2.5 kFLOP — microseconds in Rust versus ~300 us of
    /// PJRT dispatch for the same numbers on the CPU client.  The math
    /// mirrors the L1 fused kernel exactly (elu, same layer order);
    /// `runtime_integration::native_act_matches_artifact` pins the two
    /// together to 1e-4.
    pub fn q_values_native(&self, obs: &[f32]) -> Vec<f32> {
        let hidden = self.hidden_dim();
        let mut h1 = vec![0.0f32; hidden];
        let mut h2 = vec![0.0f32; hidden];
        let mut q = vec![0.0f32; self.n_actions];
        self.forward_into(obs, &mut h1, &mut h2, &mut q);
        q
    }

    /// Greedy action via the native forward (§Perf fast path).
    pub fn act_greedy_native(&self, obs: &[f32]) -> usize {
        argmax(&self.q_values_native(obs))
    }

    /// Native forward over a `[k * obs_dim]` observation batch, writing
    /// Q-values into a `[k * n_actions]` buffer — the shape a
    /// [`BatchedExecutor`](crate::coordinator::pool::BatchedExecutor)
    /// hands back, consumed without reshuffling.  Scratch is allocated
    /// once per call, not per row.
    pub fn q_values_batch_native(&self, obs_batch: &[f32], q_out: &mut [f32]) {
        assert_eq!(obs_batch.len() % self.obs_dim, 0, "ragged obs batch");
        assert_eq!(
            q_out.len() / self.n_actions,
            obs_batch.len() / self.obs_dim,
            "q buffer rows must match obs rows"
        );
        let hidden = self.hidden_dim();
        let mut h1 = vec![0.0f32; hidden];
        let mut h2 = vec![0.0f32; hidden];
        for (obs, q) in obs_batch
            .chunks_exact(self.obs_dim)
            .zip(q_out.chunks_exact_mut(self.n_actions))
        {
            self.forward_into(obs, &mut h1, &mut h2, q);
        }
    }

    /// Greedy actions for a `[k * obs_dim]` observation batch
    /// (allocation-free per row; this sits inside batched rollout loops).
    pub fn act_greedy_batch_native(&self, obs_batch: &[f32], actions: &mut [usize]) {
        assert_eq!(obs_batch.len(), actions.len() * self.obs_dim);
        let hidden = self.hidden_dim();
        let mut h1 = vec![0.0f32; hidden];
        let mut h2 = vec![0.0f32; hidden];
        let mut q = vec![0.0f32; self.n_actions];
        for (obs, a) in obs_batch.chunks_exact(self.obs_dim).zip(actions.iter_mut()) {
            self.forward_into(obs, &mut h1, &mut h2, &mut q);
            *a = argmax(&q);
        }
    }

    /// Q-values for a single observation through `dqn_act_<env>`.
    pub fn q_values(&self, rt: &mut Runtime, obs: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(obs.len(), self.obs_dim);
        let mut inputs = self.param_literals(&self.params)?;
        inputs.push(literal_f32(obs, &[1, self.obs_dim])?);
        let module = rt.load(&format!("dqn_act_{}", self.env_name))?;
        let out = module.execute_f32(&inputs)?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Greedy action for one observation.
    pub fn act_greedy(&self, rt: &mut Runtime, obs: &[f32]) -> Result<usize> {
        let q = self.q_values(rt, obs)?;
        Ok(q.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0))
    }

    /// One fused train step through `dqn_train_<env>`; returns the loss.
    pub fn train_step(&mut self, rt: &mut Runtime, batch: &Batch) -> Result<f32> {
        let b = self.batch_size;
        assert_eq!(batch.s.len(), b * self.obs_dim);
        assert_eq!(batch.a.len(), b);
        assert_eq!(batch.r.len(), b);
        assert_eq!(batch.s2.len(), b * self.obs_dim);
        assert_eq!(batch.done.len(), b);

        let mut inputs = Vec::with_capacity(30);
        inputs.extend(self.param_literals(&self.params)?);
        inputs.extend(self.param_literals(&self.target)?);
        inputs.extend(self.param_literals(&self.adam_m)?);
        inputs.extend(self.param_literals(&self.adam_v)?);
        inputs.push(scalar_f32(self.t));
        inputs.push(literal_f32(&batch.s, &[b, self.obs_dim])?);
        inputs.push(literal_i32(&batch.a));
        inputs.push(literal_f32(&batch.r, &[b])?);
        inputs.push(literal_f32(&batch.s2, &[b, self.obs_dim])?);
        inputs.push(literal_f32(&batch.done, &[b])?);

        let module = rt.load(&format!("dqn_train_{}", self.env_name))?;
        let out = module.execute_f32(&inputs)?;
        debug_assert_eq!(out.len(), 20);
        for (i, tensor) in out[0..6].iter().enumerate() {
            self.params.tensors[i].copy_from_slice(tensor);
        }
        for (i, tensor) in out[6..12].iter().enumerate() {
            self.adam_m.tensors[i].copy_from_slice(tensor);
        }
        for (i, tensor) in out[12..18].iter().enumerate() {
            self.adam_v.tensors[i].copy_from_slice(tensor);
        }
        self.t = out[18][0];
        self.steps += 1;
        Ok(out[19][0])
    }
}

/// Index of the largest Q-value (ties resolve to the last index, the
/// same rule the PJRT act path used; inputs are NaN-free by contract).
fn argmax(q: &[f32]) -> usize {
    q.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Everything here runs without a PJRT runtime: `from_spec` plus the
    // native host paths.  The artifact paths are covered by
    // rust/tests/runtime_integration.rs (gated on artifact presence).

    #[test]
    fn from_spec_builds_without_runtime() {
        let exec = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 0);
        assert_eq!(exec.obs_dim, 4);
        assert_eq!(exec.n_actions, 2);
        assert_eq!(exec.batch_size, 32);
        let q = exec.q_values_native(&[0.01, -0.02, 0.03, 0.0]);
        assert_eq!(q.len(), 2);
        assert!(q.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn from_spec_is_seed_deterministic() {
        let a = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 9);
        let b = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 9);
        assert_eq!(a.params(), b.params());
        let c = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 10);
        assert_ne!(a.params(), c.params());
    }

    #[test]
    fn batched_native_forward_matches_single() {
        let exec = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 3);
        let rows = 5;
        let obs: Vec<f32> =
            (0..rows * 4).map(|i| (i as f32 * 0.13).sin() * 0.5).collect();
        let mut q = vec![0.0f32; rows * 2];
        exec.q_values_batch_native(&obs, &mut q);
        let mut acts = vec![0usize; rows];
        exec.act_greedy_batch_native(&obs, &mut acts);
        for r in 0..rows {
            let row_obs = &obs[r * 4..(r + 1) * 4];
            assert_eq!(&q[r * 2..(r + 1) * 2], &exec.q_values_native(row_obs)[..]);
            assert_eq!(acts[r], exec.act_greedy_native(row_obs));
        }
    }
}
