//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! The manifest records, for every artifact, the operand order and
//! shapes (the HLO parameter list is positional), plus golden
//! input/output vectors the integration tests replay, plus the cartpole
//! seed parameters for bit-reproducible training runs.  Parsed with the
//! in-tree JSON reader ([`crate::core::json`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::core::error::{CairlError, Result};
use crate::core::json::{self, Value};

/// Tensor signature of one operand.
#[derive(Clone, Debug)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<TensorSig> {
        let shape = v
            .get("shape")
            .and_then(|s| s.as_array())
            .ok_or_else(|| CairlError::Runtime("tensor sig missing shape".into()))?
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let dtype = v
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("float32")
            .to_string();
        Ok(TensorSig { shape, dtype })
    }
}

/// One artifact's entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub input_names: Vec<String>,
    pub output_names: Vec<String>,
}

/// DQN hyperparameters as lowered (Table I).
#[derive(Clone, Debug)]
pub struct Hyperparameters {
    pub gamma: f64,
    pub lr: f64,
    pub adam_b1: f64,
    pub adam_b2: f64,
    pub adam_eps: f64,
    pub hidden: usize,
    pub batch: usize,
    pub huber_delta: f64,
}

/// Environment shape spec mirrored from `model.ENV_SPECS`.
#[derive(Clone, Debug)]
pub struct EnvShapeSpec {
    pub obs_dim: usize,
    pub n_actions: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub format: String,
    pub hyperparameters: Hyperparameters,
    pub env_specs: HashMap<String, EnvShapeSpec>,
    pub artifacts: HashMap<String, ArtifactMeta>,
    goldens: Value,
    init_params: Value,
    root: PathBuf,
}

/// Locate the artifact directory: `$CAIRL_ARTIFACTS` or an `artifacts/`
/// directory found by walking up from the current directory (so tests
/// work from any target subdirectory).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CAIRL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

fn names(v: &Value, key: &str) -> Vec<String> {
    v.get(key)
        .and_then(|xs| xs.as_array())
        .map(|xs| {
            xs.iter()
                .filter_map(|s| s.as_str())
                .map(String::from)
                .collect()
        })
        .unwrap_or_default()
}

impl Manifest {
    /// Load `manifest.json` from a directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            CairlError::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let doc = json::parse(&text)
            .map_err(|e| CairlError::Runtime(format!("bad manifest: {e}")))?;

        let hp = doc
            .get("hyperparameters")
            .ok_or_else(|| CairlError::Runtime("manifest missing hyperparameters".into()))?;
        let f = |k: &str| hp.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let hyperparameters = Hyperparameters {
            gamma: f("gamma"),
            lr: f("lr"),
            adam_b1: f("adam_b1"),
            adam_b2: f("adam_b2"),
            adam_eps: f("adam_eps"),
            hidden: f("hidden") as usize,
            batch: f("batch") as usize,
            huber_delta: f("huber_delta"),
        };

        let mut env_specs = HashMap::new();
        if let Some(specs) = doc.get("env_specs").and_then(|v| v.as_object()) {
            for (name, spec) in specs {
                env_specs.insert(
                    name.clone(),
                    EnvShapeSpec {
                        obs_dim: spec
                            .get("obs_dim")
                            .and_then(|v| v.as_usize())
                            .unwrap_or(0),
                        n_actions: spec
                            .get("n_actions")
                            .and_then(|v| v.as_usize())
                            .unwrap_or(0),
                    },
                );
            }
        }

        let mut artifacts = HashMap::new();
        if let Some(arts) = doc.get("artifacts").and_then(|v| v.as_object()) {
            for (name, art) in arts {
                let sigs = |key: &str| -> Result<Vec<TensorSig>> {
                    art.get(key)
                        .and_then(|xs| xs.as_array())
                        .ok_or_else(|| {
                            CairlError::Runtime(format!("{name}: missing {key}"))
                        })?
                        .iter()
                        .map(TensorSig::from_json)
                        .collect()
                };
                artifacts.insert(
                    name.clone(),
                    ArtifactMeta {
                        file: art
                            .get("file")
                            .and_then(|v| v.as_str())
                            .unwrap_or_default()
                            .to_string(),
                        inputs: sigs("inputs")?,
                        outputs: sigs("outputs")?,
                        input_names: names(art, "input_names"),
                        output_names: names(art, "output_names"),
                    },
                );
            }
        }

        Ok(Manifest {
            format: doc
                .get("format")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            hyperparameters,
            env_specs,
            artifacts,
            goldens: doc.get("goldens").cloned().unwrap_or(Value::Null),
            init_params: doc.get("init_params").cloned().unwrap_or(Value::Null),
            root: dir.to_path_buf(),
        })
    }

    /// Load from the default location.
    pub fn load_default() -> Result<Manifest> {
        Self::load(&default_artifact_dir())
    }

    /// Metadata for one artifact.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.get(name).ok_or_else(|| {
            CairlError::Runtime(format!("artifact {name:?} not in manifest"))
        })
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.root.join(&self.artifact(name)?.file))
    }

    /// Golden scalar (nested lookup, e.g. `["dqn_train_cartpole", "loss"]`).
    pub fn golden_f64(&self, path: &[&str]) -> Option<f64> {
        self.goldens.path(path)?.as_f64()
    }

    /// Golden vector.
    pub fn golden_vec(&self, path: &[&str]) -> Option<Vec<f32>> {
        self.goldens.path(path)?.as_f32_vec()
    }

    /// Seed parameter vector from `init_params` (e.g. cartpole / w1).
    pub fn init_param(&self, env: &str, name: &str) -> Option<Vec<f32>> {
        self.init_params.path(&[env, name])?.as_f32_vec()
    }

    /// All seed parameter tensors for an env in artifact order, if the
    /// manifest carries them.
    pub fn init_params_all(&self, env: &str) -> Option<Vec<Vec<f32>>> {
        let names = ["w1", "b1", "w2", "b2", "w3", "b3"];
        names
            .iter()
            .map(|n| self.init_param(env, n))
            .collect::<Option<Vec<_>>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifacts are produced by `make artifacts` (python/compile) and
    /// aren't part of the source tree; every test here inspects the
    /// generated manifest, so skip visibly when it's absent.
    fn manifest_or_skip() -> Option<Manifest> {
        match Manifest::load_default() {
            Ok(m) => Some(m),
            Err(e) => {
                eprintln!("SKIP artifact-manifest test: {e}");
                None
            }
        }
    }

    #[test]
    fn loads_and_has_expected_artifacts() {
        let Some(m) = manifest_or_skip() else {
            return;
        };
        assert_eq!(m.format, "hlo-text");
        for env in ["cartpole", "mountaincar", "acrobot", "pendulum", "multitask"] {
            assert!(m.artifacts.contains_key(&format!("dqn_act_{env}")));
            assert!(m.artifacts.contains_key(&format!("dqn_train_{env}")));
        }
        assert!(m.artifacts.contains_key("env_step_cartpole"));
        assert!(m.artifacts.contains_key("render_cartpole"));
    }

    #[test]
    fn hyperparameters_match_table_one() {
        let Some(m) = manifest_or_skip() else {
            return;
        };
        let hp = m.hyperparameters;
        assert_eq!(hp.batch, 32);
        assert_eq!(hp.hidden, 32);
        assert!((hp.gamma - 0.99).abs() < 1e-9);
        assert!((hp.lr - 3e-4).abs() < 1e-12);
    }

    #[test]
    fn train_artifact_operand_contract() {
        let Some(m) = manifest_or_skip() else {
            return;
        };
        let art = m.artifact("dqn_train_cartpole").unwrap();
        assert_eq!(art.inputs.len(), 30);
        assert_eq!(art.outputs.len(), 20);
        assert_eq!(art.input_names[24], "t");
        assert_eq!(art.input_names[25], "s");
        assert_eq!(art.output_names[19], "loss");
        // Action operand is the only i32.
        let a_idx = art.input_names.iter().position(|n| n == "a").unwrap();
        assert_eq!(art.inputs[a_idx].dtype, "int32");
        // s shape = [batch, obs_dim].
        let s_idx = art.input_names.iter().position(|n| n == "s").unwrap();
        assert_eq!(art.inputs[s_idx].shape, vec![32, 4]);
    }

    #[test]
    fn artifact_paths_exist() {
        let Some(m) = manifest_or_skip() else {
            return;
        };
        for name in m.artifacts.keys() {
            let p = m.artifact_path(name).unwrap();
            assert!(p.exists(), "{}", p.display());
        }
    }

    #[test]
    fn goldens_accessible() {
        let Some(m) = manifest_or_skip() else {
            return;
        };
        assert!(m.golden_f64(&["dqn_train_cartpole", "loss"]).unwrap() > 0.0);
        assert_eq!(m.golden_vec(&["dqn_act_cartpole", "q"]).unwrap().len(), 2);
        assert_eq!(m.init_param("cartpole", "w1").unwrap().len(), 4 * 32);
        let all = m.init_params_all("cartpole").unwrap();
        assert_eq!(all.len(), 6);
        assert_eq!(all[5].len(), 2); // b3
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(m) = manifest_or_skip() else {
            return;
        };
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn env_specs_present() {
        let Some(m) = manifest_or_skip() else {
            return;
        };
        assert_eq!(m.env_specs["cartpole"].obs_dim, 4);
        assert_eq!(m.env_specs["cartpole"].n_actions, 2);
        assert_eq!(m.env_specs["multitask"].obs_dim, 32);
    }
}
