//! PJRT runtime — loads the AOT artifacts emitted by `make artifacts`
//! and executes them from the Rust hot path.
//!
//! Python (jax) runs exactly once, at build time, to lower the L2 compute
//! graph (with the L1 Pallas kernels inlined) to HLO **text**; this
//! module parses that text with the XLA parser, compiles it on the PJRT
//! CPU client, and exposes typed executors.  No Python on the request
//! path — the binary is self-contained once `artifacts/` exists.
//!
//! * [`artifacts`] — manifest parsing (operand order, shapes, goldens).
//! * [`pjrt`] — client + executable wrapper (`HloModuleProto::from_text_file`
//!   -> `XlaComputation::from_proto` -> `client.compile` -> `execute`).
//! * [`dqn_exec`] — the Table-I DQN bound to literals: parameter store,
//!   act/train-step calls, target-network sync.

pub mod artifacts;
pub mod dqn_exec;
pub mod pjrt;

pub use artifacts::Manifest;
pub use dqn_exec::DqnExecutor;
pub use pjrt::{Module, Runtime};
