//! Sharded environment service — the out-of-process scaling axis.
//!
//! Everything below the executor layer is in-process; this module opens
//! the seam the ROADMAP named (replace the sync pool's in-process
//! broadcast with a transport) and turns a `BatchedExecutor`
//! (crate::coordinator::pool::BatchedExecutor) into a network service:
//!
//! * [`proto`] — the versioned, checksummed, sequence-numbered binary
//!   frame protocol (the normative wire spec lives in
//!   `docs/shard-protocol.md`).  Decoding is total — corrupt frames are
//!   errors, never panics.
//! * [`server`] — the `cairl serve` daemon: any executor configuration
//!   (fused kernels included) behind a Unix-socket or TCP listener.
//!   One daemon hosts many concurrent clients, each with a private
//!   executor, under an optional lane budget (`--max-lanes`, `Busy`
//!   backpressure) and auth token; a `Status` frame returns the live
//!   JSON report behind `cairl serve --status`.
//! * [`client`] — [`ShardClient`] plus [`ShardedEnvPool`], a
//!   `BatchedExecutor` over one or more remote shards with padded-obs
//!   reassembly, a configurable in-flight pipeline window
//!   ([`ShardPoolOptions::pipeline`]) and transparent failover: a lost
//!   connection is re-dialed with bounded backoff and its lanes
//!   replayed bit-exactly from the operation log, falling back to
//!   re-planning onto a surviving shard.
//! * [`plan`] — [`ShardPlan`]: cost-aware lane placement.  A quick
//!   calibration rollout measures per-env step cost and the planner
//!   cuts the mixture at cost-balanced (not lane-balanced) boundaries,
//!   keeping placement contiguous so per-lane seeds — and therefore
//!   trajectories — are bit-identical to a local pool.
//!
//! The fabric is hardened for real fleets (protocol v5): per-frame
//! read/write deadlines surface a frozen shard as
//! `CairlError::DeadlineExceeded` within a bounded window and route it
//! into the failover replay path, idle clients keep connections warm
//! with `Ping`/`Pong` heartbeats, a draining daemon (SIGTERM or
//! [`ShardServerHandle::drain`]) finishes in-flight batches while
//! answering new `Hello`s with `Busy`, and the whole stack can be
//! torture-tested deterministically with seed-driven fault injection
//! ([`crate::faults`], `--chaos PROFILE`).  Operational guidance lives
//! in `docs/OPERATIONS.md`.
//!
//! Protocol v6 adds distributed tracing: every request frame carries a
//! 16-byte trace context ([`crate::telemetry::trace::TraceCtx`]) and
//! every reply returns the server's measured decode/step durations, so
//! `cairl run --trace` stitches client and server spans into one
//! Chrome-trace timeline per batch (`docs/shard-protocol.md` §3.3).
//! Tracing never perturbs the wire semantics: an untraced context is
//! all zeroes, and failover replay re-sends each operation's original
//! context so span identities survive a reconnect.
//!
//! The layer map and the determinism contract shared by every executor
//! (local, fused, sharded, pipelined, post-failover) are documented
//! once in `docs/ARCHITECTURE.md`.
//!
//! ## Runnable example
//!
//! Serve a mixture on one shard and run a pipelined seeded workload
//! against it (the same spec/seed on `--executor vec` reproduces the
//! identical episode returns — the CI shard-smoke job diffs exactly
//! that, including with a shard killed mid-run):
//!
//! ```text
//! cairl serve --env "CartPole-v1:6,MountainCar-v0:2" \
//!     --listen unix:///tmp/cairl-s0.sock --executor pool --threads 2 &
//! cairl run --env "CartPole-v1:6,MountainCar-v0:2" --steps 8000 --seed 11 \
//!     --shard unix:///tmp/cairl-s0.sock --pipeline 4
//! cairl serve --status unix:///tmp/cairl-s0.sock
//! ```
//!
//! In-process, the same round trip:
//!
//! ```no_run
//! use cairl::coordinator::pool::BatchedExecutor;
//! use cairl::shard::{ServeConfig, ShardServer, ShardedEnvPool};
//!
//! let server = ShardServer::bind("tcp://127.0.0.1:0", ServeConfig::new("CartPole-v1")).unwrap();
//! let addr = server.local_addr();
//! let handle = server.spawn();
//! let pool = ShardedEnvPool::connect(&[addr], "CartPole-v1", 8, 7).unwrap();
//! assert_eq!(pool.num_lanes(), 8);
//! # drop(pool);
//! handle.shutdown();
//! ```

#![warn(missing_docs)]

pub mod client;
pub mod net;
pub mod plan;
pub mod proto;
pub mod server;

pub use client::{
    shard_status, ConnectOptions, FailoverConfig, ShardClient, ShardPoolOptions, ShardedEnvPool,
    MAX_PIPELINE,
};
pub use net::ShardAddr;
pub use plan::{calibrate_costs, ShardAssignment, ShardPlan};
pub use server::{ServeConfig, ServerStats, ShardServer, ShardServerHandle};
