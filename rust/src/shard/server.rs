//! The shard daemon: a [`BatchedExecutor`] behind a socket.
//!
//! `cairl serve --env <spec> --lanes N --listen <addr>` hosts the
//! configured executor machinery (fused kernels included) behind a
//! Unix-socket or TCP listener.  One framed stream per client, and one
//! **private executor per connection**: the client's `Hello` names the
//! env spec it wants (its slice of a sharded mixture — empty for the
//! daemon's configured default), the pool-wide base seed and its first
//! global lane, and the daemon builds a fresh executor seeded exactly
//! as a local pool would seed those lanes.  Per-connection executors
//! are what make the determinism contract trivial: two clients can
//! never interleave steps into each other's trajectories.
//!
//! Inside a connection the protocol is strict request/reply
//! (`Reset`→`Obs`, `Step`→`StepResult`,
//! `RandomRollout`→`RolloutDone`), with every batch drained into the
//! executor's `step_into` — the sync pool then fans it out over its
//! worker `step_batch` groups as usual.  Malformed frames, bad specs,
//! wrong action counts and executor panics all answer with an `Error`
//! frame before the connection closes; the daemon itself never goes
//! down with a client.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::experiment::{
    build_env_pool_shard, build_executor_with_kernel, ExecutorKind, KernelMode,
};
use crate::coordinator::pool::{BatchedExecutor, EnvPool, RolloutCounts};
use crate::coordinator::registry::{self, MixtureSpec};
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::shard::net::{FramedStream, RawStream, ShardAddr, ShardListener};
use crate::shard::proto::{Msg, MsgRef};

/// What a shard daemon hosts: the default env spec plus the executor
/// knobs every connection's pool is built with.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Default env spec (bare id or mixture) for clients whose `Hello`
    /// does not name one.
    pub env_spec: String,
    /// Executor kind behind every connection ([`ExecutorKind::PoolSync`]
    /// is the default and the only kind that serves `RandomRollout`).
    pub kind: ExecutorKind,
    /// Lane count when the spec is a bare id (mixtures carry their own).
    pub lanes: usize,
    /// Worker threads per connection executor (`0` = one per core).
    pub threads: usize,
    /// Stepping kernel ([`KernelMode::Fused`] by default).
    pub kernel: KernelMode,
}

impl ServeConfig {
    /// Defaults: sync pool, one lane, all cores, fused kernels.
    pub fn new(env_spec: &str) -> ServeConfig {
        ServeConfig {
            env_spec: env_spec.to_string(),
            kind: ExecutorKind::PoolSync,
            lanes: 1,
            threads: 0,
            kernel: KernelMode::default(),
        }
    }

    fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }
}

/// The executor behind one connection.  The sync pool is kept concrete
/// so the whole-workload `RandomRollout` command can run worker-side
/// ([`EnvPool::random_rollout`] — one barrier, zero per-step frames).
enum HostExec {
    Pool(EnvPool),
    Boxed(Box<dyn BatchedExecutor>),
}

impl HostExec {
    fn exec(&mut self) -> &mut dyn BatchedExecutor {
        match self {
            HostExec::Pool(pool) => pool,
            HostExec::Boxed(exec) => exec.as_mut(),
        }
    }

    fn random_rollout(&mut self, steps_per_lane: u64) -> Option<RolloutCounts> {
        match self {
            HostExec::Pool(pool) => Some(pool.random_rollout(steps_per_lane)),
            HostExec::Boxed(_) => None,
        }
    }
}

/// A bound-but-not-yet-serving shard daemon.
pub struct ShardServer {
    listener: ShardListener,
    config: Arc<ServeConfig>,
}

impl ShardServer {
    /// Bind `addr` (`unix://...` or `tcp://...`) and validate the
    /// configured default spec eagerly, so a typo fails here and not on
    /// the first client.
    pub fn bind(addr: &str, config: ServeConfig) -> Result<ShardServer> {
        validate_spec(&config.env_spec)?;
        let addr = ShardAddr::parse(addr)?;
        let listener = ShardListener::bind(&addr)?;
        Ok(ShardServer {
            listener,
            config: Arc::new(config),
        })
    }

    /// The bound address in dialable form (TCP reports the real port).
    pub fn local_addr(&self) -> String {
        self.listener.local_addr()
    }

    /// Serve until the process exits — the `cairl serve` foreground path.
    pub fn run(self) -> Result<()> {
        accept_loop(self.listener, self.config, None);
        Ok(())
    }

    /// Serve on a background thread; the returned handle shuts the
    /// accept loop down on [`ShardServerHandle::shutdown`] or drop.
    /// In-flight connections drain on their own when clients hang up.
    pub fn spawn(self) -> ShardServerHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let addr = self.local_addr();
        let stop_thread = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("cairl-shard-accept".into())
            .spawn(move || accept_loop(self.listener, self.config, Some(stop_thread)))
            .expect("spawn shard accept loop");
        ShardServerHandle {
            stop,
            handle: Some(handle),
            addr,
        }
    }
}

/// Handle to a background [`ShardServer`]; see [`ShardServer::spawn`].
pub struct ShardServerHandle {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    addr: String,
}

impl ShardServerHandle {
    /// The served address (dialable).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for ShardServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Eager validation of an env spec string (bare id or mixture).
fn validate_spec(spec: &str) -> Result<()> {
    if spec.is_empty() {
        return Err(CairlError::Config("serve needs a non-empty env spec".into()));
    }
    if MixtureSpec::is_mixture(spec) {
        MixtureSpec::parse(spec).map(|_| ())
    } else {
        registry::validate(spec)
    }
}

/// Poll-accept until stopped (or forever when `stop` is `None`); each
/// connection gets its own detached thread.
fn accept_loop(listener: ShardListener, config: Arc<ServeConfig>, stop: Option<Arc<AtomicBool>>) {
    loop {
        if let Some(flag) = &stop {
            if flag.load(Ordering::Acquire) {
                return;
            }
        }
        match listener.accept_nonblocking() {
            Ok(Some(stream)) => {
                let config = Arc::clone(&config);
                let _ = std::thread::Builder::new()
                    .name("cairl-shard-conn".into())
                    .spawn(move || serve_conn(stream, &config));
            }
            Ok(None) => std::thread::sleep(Duration::from_millis(2)),
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Best-effort error reply; the connection closes either way.
fn bail(stream: &mut FramedStream, message: &str) {
    let _ = stream.send(MsgRef::Error { message });
}

/// One connection: handshake, then request/reply until `Close`/EOF.
fn serve_conn(stream: RawStream, config: &ServeConfig) {
    let Ok(mut stream) = FramedStream::new(stream) else {
        return;
    };
    let mut host: Option<HostExec> = None;
    // Reusable step/reset buffers, sized at handshake.
    let mut obs: Vec<f32> = Vec::new();
    let mut transitions: Vec<Transition> = Vec::new();

    loop {
        let msg = match stream.recv() {
            Ok(msg) => msg,
            Err(CairlError::Io(_)) => return, // peer hung up
            Err(e) => {
                bail(&mut stream, &format!("bad frame: {e}"));
                return;
            }
        };
        match msg {
            Msg::Hello {
                spec,
                base_seed,
                first_lane,
            } => {
                let spec = if spec.is_empty() {
                    config.env_spec.clone()
                } else {
                    spec
                };
                let threads = config.effective_threads();
                let built: Result<HostExec> = match config.kind {
                    // Keep the sync pool concrete so RandomRollout can
                    // run worker-side with the *global* lane streams.
                    ExecutorKind::PoolSync => build_env_pool_shard(
                        &spec,
                        config.lanes,
                        threads,
                        base_seed,
                        first_lane as usize,
                        config.kernel,
                    )
                    .map(HostExec::Pool),
                    kind => build_executor_with_kernel(
                        &spec,
                        kind,
                        config.lanes,
                        threads,
                        base_seed + first_lane,
                        &[],
                        config.kernel,
                    )
                    .map(HostExec::Boxed),
                };
                match built {
                    Ok(mut built) => {
                        let exec = built.exec();
                        let n = exec.num_lanes();
                        let d = exec.obs_dim();
                        obs = vec![0.0f32; n * d];
                        transitions = vec![Transition::default(); n];
                        if stream
                            .send(MsgRef::Spec {
                                obs_dim: d as u64,
                                lane_specs: exec.lane_specs(),
                            })
                            .is_err()
                        {
                            return;
                        }
                        host = Some(built);
                    }
                    Err(e) => {
                        bail(&mut stream, &format!("cannot host {spec:?}: {e}"));
                        return;
                    }
                }
            }
            Msg::Reset => {
                let Some(host) = host.as_mut() else {
                    bail(&mut stream, "Reset before Hello");
                    return;
                };
                let ok = catch_exec(|| host.exec().reset_into(&mut obs));
                if !ok {
                    bail(&mut stream, "executor panicked during Reset");
                    return;
                }
                if stream.send(MsgRef::Obs { obs: &obs }).is_err() {
                    return;
                }
            }
            Msg::Step { actions } => {
                let Some(host) = host.as_mut() else {
                    bail(&mut stream, "Step before Hello");
                    return;
                };
                if actions.len() != transitions.len() {
                    bail(
                        &mut stream,
                        &format!(
                            "Step carried {} actions for {} lanes",
                            actions.len(),
                            transitions.len()
                        ),
                    );
                    return;
                }
                let ok =
                    catch_exec(|| host.exec().step_into(&actions, &mut obs, &mut transitions));
                if !ok {
                    bail(&mut stream, "executor panicked during Step");
                    return;
                }
                if stream
                    .send(MsgRef::StepResult {
                        obs: &obs,
                        transitions: &transitions,
                    })
                    .is_err()
                {
                    return;
                }
            }
            Msg::RandomRollout { steps_per_lane } => {
                let Some(host) = host.as_mut() else {
                    bail(&mut stream, "RandomRollout before Hello");
                    return;
                };
                let mut counts = None;
                let ok = catch_exec(|| counts = host.random_rollout(steps_per_lane));
                if !ok {
                    bail(&mut stream, "executor panicked during RandomRollout");
                    return;
                }
                match counts {
                    Some(c) => {
                        if stream
                            .send(MsgRef::RolloutDone {
                                steps: c.steps,
                                episodes: c.episodes,
                            })
                            .is_err()
                        {
                            return;
                        }
                    }
                    None => {
                        bail(
                            &mut stream,
                            "RandomRollout needs a pool-sync shard (serve --executor pool)",
                        );
                        return;
                    }
                }
            }
            Msg::Close => return,
            other => {
                bail(&mut stream, &format!("unexpected message {other:?}"));
                return;
            }
        }
    }
}

/// Run an executor call, converting a panic (a poisoned pool) into a
/// clean `false` so the client gets an `Error` frame instead of EOF.
fn catch_exec(f: impl FnOnce()) -> bool {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).is_ok()
}
