//! The shard daemon: a [`BatchedExecutor`] behind a socket.
//!
//! `cairl serve --env <spec> --lanes N --listen <addr>` hosts the
//! configured executor machinery (fused kernels included) behind a
//! Unix-socket or TCP listener.  The daemon is **multi-tenant**: one
//! shared listener, any number of concurrent clients, and one **private
//! executor per connection** — the client's `Hello` names the env spec
//! it wants (its slice of a sharded mixture, empty for the daemon's
//! configured default), the pool-wide base seed and its first global
//! lane, and the daemon builds a fresh executor seeded exactly as a
//! local pool would seed those lanes.  Per-connection executors are
//! what make the determinism contract trivial: two clients can never
//! interleave steps into each other's trajectories.
//!
//! **Wrapper chains.**  `cairl serve --wrap CHAIN` sets a default
//! pool-level wrapper chain for every hosted lane; a client's `Hello`
//! may carry its own chain in the `wrap` field (protocol v3), which
//! overrides the default for that connection.  Per-component `+`
//! chains travel inside the mixture spec itself, so a sharded
//! `"CartPole-v1+NormalizeObs:8"` builds exactly the lane groups a
//! local pool would.
//!
//! **Admission control.**  `--max-lanes N` caps the summed lane count
//! across live connections; a `Hello` that would exceed the budget is
//! answered with a `Busy` frame (current/maximum lanes plus a suggested
//! back-off) and the connection stays open for a retry.  `--token T`
//! requires every `Hello`/`Status` to carry the same token — transport
//! security stays out of scope (run TCP shards behind an SSH tunnel or
//! on a trusted network; see README).
//!
//! **Introspection.**  A `Status` request — valid before any `Hello`,
//! which is how `cairl serve --status <addr>` works — answers with a
//! JSON [`ServerStats`] snapshot: uptime, lane budget, frame/step
//! totals, reconnect count and a per-client table.
//!
//! **Robustness (protocol v5).**  A `Ping` answers `Pong` at any point
//! — before any `Hello` and without a token — which is what lets idle
//! clients heartbeat.  `--read-timeout MS` arms a per-connection read
//! deadline: a peer silent for longer (no request, no `Ping`) is
//! reaped and its lanes released.  `--chaos PROFILE` arms a seed-driven
//! wire-fault injector on every connection right after its `Spec`
//! reply (see [`crate::faults`]; the handshake always runs clean), and
//! `--on-panic quarantine` trades the poison-by-default executor
//! behaviour for per-lane quarantine.  SIGTERM on the foreground
//! daemon — and [`ShardServerHandle::drain`] /
//! [`ShardServerHandle::shutdown_graceful`] on a background one —
//! starts a **drain**: in-flight connections keep being served, new
//! `Hello`s answer `Busy`, and the daemon exits once every connection
//! has wound down or the grace window lapses.  The runbook view of all
//! of this lives in `docs/OPERATIONS.md`.
//!
//! **Tracing (protocol v6).**  Every request carries a 16-byte trace
//! context; for a traced request (context nonzero) the daemon measures
//! its payload decode and the executor call and ships both durations
//! back in the reply's [`ServerTiming`] block.  The client synthesizes
//! the matching `decode`/`server_step` spans centered inside its
//! observed wire window — durations cross the wire, clocks never do —
//! so the server-side work stitches under the client's batch span
//! without the daemon exporting anything itself.
//!
//! Inside a connection the protocol is sequenced request/reply
//! (`Reset`→`Obs`, `Step`→`StepResult`, `RandomRollout`→`RolloutDone`):
//! the daemon enforces the strict-successor rule on request sequence
//! numbers and echoes each request's seq on its reply, which is what
//! lets a client keep several batches in flight (pipelining) and still
//! pair every reply with its request.  Requests are processed strictly
//! in order.  Malformed frames, bad sequence numbers, bad tokens, bad
//! specs, wrong action counts and executor panics all answer with an
//! `Error` frame before the connection closes; the daemon itself never
//! goes down with a client.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::experiment::{
    build_env_pool_shard, build_executor_with_kernel, ExecutorKind, KernelMode,
};
use crate::coordinator::pool::{BatchedExecutor, EnvPool, PanicPolicy, RolloutCounts};
use crate::coordinator::registry::{self, MixtureSpec};
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::core::json::Value;
use crate::faults::{ChaosProfile, FaultPlan};
use crate::telemetry::{self, counter, gauge, Counter, Gauge};
use crate::wrappers::WrapperSpec;
use crate::shard::net::{FramedStream, RawStream, ShardAddr, ShardListener};
use crate::shard::proto::{Msg, MsgRef, SeqTracker, ServerTiming, PROTO_VERSION, SEQ_NONE};
use crate::telemetry::trace::{self, TraceCtx};

/// Back-off the daemon suggests in a `Busy` frame.
const BUSY_RETRY_MS: u64 = 50;

/// Grace window a SIGTERM-initiated drain gives in-flight connections
/// before the foreground daemon exits anyway.
const DRAIN_GRACE: Duration = Duration::from_secs(30);

/// Idle back-off ceiling for the poll-accept loop: sleeps start at
/// 1 ms, double per idle poll up to this cap, and reset on any accept.
const ACCEPT_IDLE_CAP_MS: u64 = 20;

/// What a shard daemon hosts: the default env spec plus the executor
/// knobs every connection's pool is built with.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Default env spec (bare id or mixture) for clients whose `Hello`
    /// does not name one.
    pub env_spec: String,
    /// Executor kind behind every connection ([`ExecutorKind::PoolSync`]
    /// is the default and the only kind that serves `RandomRollout`).
    pub kind: ExecutorKind,
    /// Lane count when the spec is a bare id (mixtures carry their own).
    pub lanes: usize,
    /// Worker threads per connection executor (`0` = one per core).
    pub threads: usize,
    /// Stepping kernel ([`KernelMode::Fused`] by default).
    pub kernel: KernelMode,
    /// Admission budget: summed lanes across live connections (`0` =
    /// unlimited).  A `Hello` over budget answers `Busy`.
    pub max_lanes: usize,
    /// Shared-secret auth token (`""` = no auth).  Checked on every
    /// `Hello` and `Status`.
    pub token: String,
    /// Default pool-level wrapper chain (`--wrap` grammar, e.g.
    /// `"TimeLimit(200),NormalizeObs"`) applied to every hosted lane
    /// when a client's `Hello` carries an empty `wrap` field.  A
    /// non-empty `Hello.wrap` overrides it for that connection.
    pub wrap: String,
    /// Comma-separated peer-address prefixes admitted at accept time
    /// (`""` = everyone).  A TCP peer must render (`"ip:port"`) with one
    /// of the prefixes — `"127.0.0.1"` admits every local port,
    /// `"10.0."` a subnet.  Unix-socket peers are always admitted
    /// (filesystem permissions already scope them).  Complements
    /// `--token`: the token authenticates inside the protocol, the
    /// allow list rejects before a single frame is read.
    pub allow: String,
    /// Per-connection read deadline (`None` = wait forever).  With a
    /// deadline armed, a peer silent for longer — no request, no
    /// `Ping` — is reaped: the blocked read surfaces as
    /// [`CairlError::DeadlineExceeded`] and the connection closes,
    /// releasing its lanes.  Clients that idle between batches should
    /// heartbeat at an interval comfortably below this (see
    /// `ConnectOptions::heartbeat`).
    pub read_timeout: Option<Duration>,
    /// Seed-driven wire-fault injector armed on every connection right
    /// after its `Spec` reply — the handshake itself always runs clean.
    /// `None` (or a profile whose [`ChaosProfile::is_off`] holds)
    /// serves faithfully.
    pub chaos: Option<ChaosProfile>,
    /// What a hosted executor does when an env panics mid-batch:
    /// poison the whole pool (the default — fail fast, the client gets
    /// an `Error` frame) or quarantine just the offending lane.
    pub on_panic: PanicPolicy,
}

impl ServeConfig {
    /// Defaults: sync pool, one lane, all cores, fused kernels, no lane
    /// budget, no auth token.
    pub fn new(env_spec: &str) -> ServeConfig {
        ServeConfig {
            env_spec: env_spec.to_string(),
            kind: ExecutorKind::PoolSync,
            lanes: 1,
            threads: 0,
            kernel: KernelMode::default(),
            max_lanes: 0,
            token: String::new(),
            wrap: String::new(),
            allow: String::new(),
            read_timeout: None,
            chaos: None,
            on_panic: PanicPolicy::Poison,
        }
    }

    fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }
}

/// The executor behind one connection.  The sync pool is kept concrete
/// so the whole-workload `RandomRollout` command can run worker-side
/// ([`EnvPool::random_rollout`] — one barrier, zero per-step frames).
enum HostExec {
    Pool(EnvPool),
    Boxed(Box<dyn BatchedExecutor>),
}

impl HostExec {
    fn exec(&mut self) -> &mut dyn BatchedExecutor {
        match self {
            HostExec::Pool(pool) => pool,
            HostExec::Boxed(exec) => exec.as_mut(),
        }
    }

    fn random_rollout(&mut self, steps_per_lane: u64) -> Option<RolloutCounts> {
        match self {
            HostExec::Pool(pool) => Some(pool.random_rollout(steps_per_lane)),
            HostExec::Boxed(_) => None,
        }
    }
}

/// One connected client's slice of the status report.
struct ClientEntry {
    spec: String,
    lanes: usize,
    pipeline: u32,
    frames: u64,
    steps: u64,
    since: Instant,
}

/// Shared daemon counters behind [`ShardServer`]/[`ShardServerHandle`]:
/// everything `cairl serve --status` reports.  All methods are safe to
/// call from any thread while the daemon serves.
pub struct ServerStats {
    started: Instant,
    max_lanes: usize,
    total_connections: AtomicU64,
    hellos: AtomicU64,
    reconnects: AtomicU64,
    busy_rejections: AtomicU64,
    auth_failures: AtomicU64,
    frames: AtomicU64,
    steps: AtomicU64,
    active_lanes: AtomicUsize,
    rejected_peers: AtomicU64,
    /// Telemetry mirrors of the daemon counters, so `cairl metrics`
    /// sees the serve fabric alongside executor and shard-client series.
    m_connections: Counter,
    m_frames: Counter,
    m_bad_frames: Counter,
    m_rejected_peers: Counter,
    m_active_lanes: Gauge,
    clients: Mutex<BTreeMap<u64, ClientEntry>>,
    /// `(spec, wrap, base_seed, first_lane)` tuples seen across the
    /// daemon's lifetime: a repeat is a client re-handshaking after a
    /// connection loss, i.e. a failover reconnect.
    origins: Mutex<BTreeMap<(String, String, u64, u64), u64>>,
}

impl ServerStats {
    fn new(max_lanes: usize) -> ServerStats {
        ServerStats {
            started: Instant::now(),
            max_lanes,
            total_connections: AtomicU64::new(0),
            hellos: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            busy_rejections: AtomicU64::new(0),
            auth_failures: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            steps: AtomicU64::new(0),
            active_lanes: AtomicUsize::new(0),
            rejected_peers: AtomicU64::new(0),
            m_connections: counter("cairl_serve_connections_total"),
            m_frames: counter("cairl_serve_frames_total"),
            m_bad_frames: counter("cairl_serve_bad_frames_total"),
            m_rejected_peers: counter("cairl_serve_rejected_peers_total"),
            m_active_lanes: gauge("cairl_serve_active_lanes"),
            clients: Mutex::new(BTreeMap::new()),
            origins: Mutex::new(BTreeMap::new()),
        }
    }

    /// Lanes currently reserved by connected clients.
    pub fn active_lanes(&self) -> usize {
        self.active_lanes.load(Ordering::Relaxed)
    }

    /// Connections that have completed a `Hello` and hold an executor.
    pub fn active_clients(&self) -> usize {
        self.clients.lock().map(|c| c.len()).unwrap_or(0)
    }

    /// `Hello`s refused with a `Busy` frame over the daemon's lifetime.
    pub fn busy_rejections(&self) -> u64 {
        self.busy_rejections.load(Ordering::Relaxed)
    }

    /// `Hello`s that re-presented a previously-seen seeding origin — a
    /// client re-handshaking after losing its connection.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Valid frames received over the daemon's lifetime.
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    /// Lane-steps served over the daemon's lifetime.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Connections rejected by the `--allow` peer list at accept time.
    pub fn rejected_peers(&self) -> u64 {
        self.rejected_peers.load(Ordering::Relaxed)
    }

    /// Count an `--allow` rejection (accept-time, pre-protocol).
    fn note_rejected_peer(&self) {
        self.rejected_peers.fetch_add(1, Ordering::Relaxed);
        self.m_rejected_peers.inc();
    }

    /// Count a frame the connection loop could not decode (corruption,
    /// checksum/length mismatch) or that violated request sequencing.
    fn note_bad_frame(&self) {
        self.m_bad_frames.inc();
    }

    /// Reserve `lanes` against the budget; `false` = over budget.
    fn try_reserve(&self, lanes: usize) -> bool {
        if self.max_lanes == 0 {
            self.active_lanes.fetch_add(lanes, Ordering::Relaxed);
            self.m_active_lanes.set(self.active_lanes() as i64);
            return true;
        }
        let mut cur = self.active_lanes.load(Ordering::Relaxed);
        loop {
            if cur + lanes > self.max_lanes {
                return false;
            }
            match self.active_lanes.compare_exchange(
                cur,
                cur + lanes,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.m_active_lanes.set(self.active_lanes() as i64);
                    return true;
                }
                Err(now) => cur = now,
            }
        }
    }

    fn release_lanes(&self, lanes: usize) {
        if lanes > 0 {
            self.active_lanes.fetch_sub(lanes, Ordering::Relaxed);
            self.m_active_lanes.set(self.active_lanes() as i64);
        }
    }

    fn register_client(&self, id: u64, spec: &str, lanes: usize, pipeline: u32) {
        if let Ok(mut clients) = self.clients.lock() {
            clients.insert(
                id,
                ClientEntry {
                    spec: spec.to_string(),
                    lanes,
                    pipeline,
                    frames: 0,
                    steps: 0,
                    since: Instant::now(),
                },
            );
        }
    }

    /// Remove `id`'s entry (if any) and release its lane reservation.
    /// Runs on connection end and on a re-`Hello`.
    fn drop_client(&self, id: u64) {
        let lanes = self
            .clients
            .lock()
            .ok()
            .and_then(|mut c| c.remove(&id))
            .map(|e| e.lanes)
            .unwrap_or(0);
        self.release_lanes(lanes);
    }

    /// Global + per-client frame/step accounting for one request.
    fn note_request(&self, id: u64, steps: u64) {
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.m_frames.inc();
        if steps > 0 {
            self.steps.fetch_add(steps, Ordering::Relaxed);
        }
        if let Ok(mut clients) = self.clients.lock() {
            if let Some(entry) = clients.get_mut(&id) {
                entry.frames += 1;
                entry.steps += steps;
            }
        }
    }

    /// Record a `Hello`'s seeding origin (wrap chain included — a
    /// different chain is a different trajectory); a repeat counts as a
    /// failover reconnect.
    fn note_origin(&self, spec: &str, wrap: &str, base_seed: u64, first_lane: u64) {
        self.hellos.fetch_add(1, Ordering::Relaxed);
        if let Ok(mut origins) = self.origins.lock() {
            let count = origins
                .entry((spec.to_string(), wrap.to_string(), base_seed, first_lane))
                .or_insert(0);
            if *count > 0 {
                self.reconnects.fetch_add(1, Ordering::Relaxed);
            }
            *count += 1;
        }
    }

    /// Render the status snapshot as a compact JSON document — the
    /// `StatusReport` payload and the `cairl serve --status` output.
    pub fn render_status(&self) -> String {
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        let frames = self.frames() as f64;
        let steps = self.steps() as f64;
        let mut doc = BTreeMap::new();
        doc.insert("proto_version".into(), Value::Num(PROTO_VERSION as f64));
        doc.insert("uptime_secs".into(), Value::Num(uptime));
        doc.insert(
            "total_connections".into(),
            Value::Num(self.total_connections.load(Ordering::Relaxed) as f64),
        );
        doc.insert(
            "hellos".into(),
            Value::Num(self.hellos.load(Ordering::Relaxed) as f64),
        );
        doc.insert("reconnects".into(), Value::Num(self.reconnects() as f64));
        doc.insert(
            "busy_rejections".into(),
            Value::Num(self.busy_rejections() as f64),
        );
        doc.insert(
            "auth_failures".into(),
            Value::Num(self.auth_failures.load(Ordering::Relaxed) as f64),
        );
        doc.insert("frames".into(), Value::Num(frames));
        doc.insert("frames_per_sec".into(), Value::Num(frames / uptime));
        doc.insert("steps".into(), Value::Num(steps));
        doc.insert("steps_per_sec".into(), Value::Num(steps / uptime));
        doc.insert("active_lanes".into(), Value::Num(self.active_lanes() as f64));
        doc.insert("max_lanes".into(), Value::Num(self.max_lanes as f64));
        doc.insert(
            "rejected_peers".into(),
            Value::Num(self.rejected_peers() as f64),
        );
        // The whole process-wide metrics registry rides along, so
        // `cairl metrics --addr ADDR` can render Prometheus text from
        // one status round-trip.
        doc.insert("metrics".into(), telemetry::snapshot());
        let clients: Vec<Value> = self
            .clients
            .lock()
            .map(|clients| {
                clients
                    .iter()
                    .map(|(id, e)| {
                        let mut c = BTreeMap::new();
                        c.insert("id".into(), Value::Num(*id as f64));
                        c.insert("spec".into(), Value::Str(e.spec.clone()));
                        c.insert("lanes".into(), Value::Num(e.lanes as f64));
                        c.insert("pipeline".into(), Value::Num(e.pipeline as f64));
                        c.insert("frames".into(), Value::Num(e.frames as f64));
                        c.insert("steps".into(), Value::Num(e.steps as f64));
                        c.insert(
                            "connected_secs".into(),
                            Value::Num(e.since.elapsed().as_secs_f64()),
                        );
                        Value::Object(c)
                    })
                    .collect()
            })
            .unwrap_or_default();
        doc.insert("active_clients".into(), Value::Num(clients.len() as f64));
        doc.insert("clients".into(), Value::Array(clients));
        Value::Object(doc).render()
    }
}

/// Shutdown/drain switchboard shared by the accept loop, every
/// connection thread and the [`ShardServerHandle`].  `stop` ends the
/// accept loop immediately; `drain` keeps it serving but bounces new
/// `Hello`s with `Busy` until every connection has wound down or the
/// grace deadline lapses.
struct ServeControl {
    stop: AtomicBool,
    drain: AtomicBool,
    deadline: Mutex<Option<Instant>>,
}

impl ServeControl {
    fn new() -> ServeControl {
        ServeControl {
            stop: AtomicBool::new(false),
            drain: AtomicBool::new(false),
            deadline: Mutex::new(None),
        }
    }

    /// Enter drain mode; the first caller's grace window wins.
    fn begin_drain(&self, grace: Duration) {
        self.drain.store(true, Ordering::Release);
        if let Ok(mut deadline) = self.deadline.lock() {
            if deadline.is_none() {
                *deadline = Some(Instant::now() + grace);
            }
        }
    }

    fn draining(&self) -> bool {
        self.drain.load(Ordering::Acquire)
    }

    fn drain_expired(&self) -> bool {
        self.deadline
            .lock()
            .ok()
            .and_then(|d| *d)
            .map(|d| Instant::now() >= d)
            .unwrap_or(false)
    }
}

/// Set by the SIGTERM handler the foreground daemon installs; the
/// accept loop polls it and turns it into a drain.
static TERM_FLAG: AtomicBool = AtomicBool::new(false);

extern "C" fn on_sigterm(_signum: i32) {
    // Only async-signal-safe work here: flip the flag, nothing else.
    TERM_FLAG.store(true, Ordering::SeqCst);
}

/// Install [`on_sigterm`] as the process's SIGTERM handler via the
/// libc `signal(2)` entry point — declared directly so the crate stays
/// dependency-free.
fn install_sigterm_handler() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_sigterm as usize);
    }
}

/// Live connections, by id — the raw handles let
/// [`ShardServerHandle::kill_connections`] sever every client at once
/// (the failover drill in tests and CI).
type ConnTable = Mutex<Vec<(u64, RawStream)>>;

/// A bound-but-not-yet-serving shard daemon.
pub struct ShardServer {
    listener: ShardListener,
    config: Arc<ServeConfig>,
    stats: Arc<ServerStats>,
    conns: Arc<ConnTable>,
    control: Arc<ServeControl>,
}

impl ShardServer {
    /// Bind `addr` (`unix://...` or `tcp://...`) and validate the
    /// configured default spec eagerly, so a typo fails here and not on
    /// the first client.
    ///
    /// # Example: the serve handshake end to end
    ///
    /// ```
    /// use cairl::shard::{ServeConfig, ShardClient, ShardServer};
    ///
    /// let mut config = ServeConfig::new("CartPole-v1");
    /// config.lanes = 2;
    /// config.threads = 1;
    /// let server = ShardServer::bind("tcp://127.0.0.1:0", config).unwrap();
    /// let handle = server.spawn();
    ///
    /// // Hello -> Spec: the daemon builds a private 2-lane executor
    /// // seeded like local lanes [0, 2) and reports its lane metadata.
    /// let client = ShardClient::connect(handle.addr(), "CartPole-v1:2", 7, 0).unwrap();
    /// assert_eq!(client.num_lanes(), 2);
    /// assert_eq!(client.obs_dim(), 4);
    /// drop(client);
    /// handle.shutdown();
    /// ```
    pub fn bind(addr: &str, config: ServeConfig) -> Result<ShardServer> {
        validate_spec(&config.env_spec)?;
        // Validate the default wrap chain eagerly too: a typo in
        // `serve --wrap` fails at bind, not on the first bare Hello.
        WrapperSpec::parse_chain(&config.wrap)?;
        let addr = ShardAddr::parse(addr)?;
        let listener = ShardListener::bind(&addr)?;
        let stats = Arc::new(ServerStats::new(config.max_lanes));
        Ok(ShardServer {
            listener,
            config: Arc::new(config),
            stats,
            conns: Arc::new(Mutex::new(Vec::new())),
            control: Arc::new(ServeControl::new()),
        })
    }

    /// The bound address in dialable form (TCP reports the real port).
    pub fn local_addr(&self) -> String {
        self.listener.local_addr()
    }

    /// The daemon's shared counters (lives on after `run`/`spawn`).
    pub fn stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Serve until shut down — the `cairl serve` foreground path.
    /// Installs a SIGTERM handler that drains: in-flight connections
    /// finish their pipelined batches, new `Hello`s answer `Busy`, and
    /// the daemon exits once every connection has wound down or
    /// [`DRAIN_GRACE`] lapses.
    pub fn run(self) -> Result<()> {
        install_sigterm_handler();
        accept_loop(
            self.listener,
            self.config,
            self.stats,
            Arc::clone(&self.conns),
            Arc::clone(&self.control),
            true,
        );
        // Sever any connection that outlived the drain grace window.
        if let Ok(conns) = self.conns.lock() {
            for (_, raw) in conns.iter() {
                raw.shutdown();
            }
        }
        Ok(())
    }

    /// Serve on a background thread; the returned handle shuts the
    /// accept loop down on [`ShardServerHandle::shutdown`] or drop.
    /// In-flight connections drain on their own when clients hang up.
    pub fn spawn(self) -> ShardServerHandle {
        let addr = self.local_addr();
        let stats = Arc::clone(&self.stats);
        let conns = Arc::clone(&self.conns);
        let control = Arc::clone(&self.control);
        let handle = std::thread::Builder::new()
            .name("cairl-shard-accept".into())
            .spawn(move || {
                accept_loop(
                    self.listener,
                    self.config,
                    self.stats,
                    self.conns,
                    self.control,
                    false,
                )
            })
            .expect("spawn shard accept loop");
        ShardServerHandle {
            control,
            handle: Some(handle),
            addr,
            stats,
            conns,
        }
    }
}

/// Handle to a background [`ShardServer`]; see [`ShardServer::spawn`].
pub struct ShardServerHandle {
    control: Arc<ServeControl>,
    handle: Option<JoinHandle<()>>,
    addr: String,
    stats: Arc<ServerStats>,
    conns: Arc<ConnTable>,
}

impl ShardServerHandle {
    /// The served address (dialable).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The daemon's shared counters.
    pub fn stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Sever every live connection (the accept loop keeps running, so
    /// clients can re-dial and replay — the failover drill).  Returns
    /// the number of connections cut.
    pub fn kill_connections(&self) -> usize {
        match self.conns.lock() {
            Ok(conns) => {
                for (_, raw) in conns.iter() {
                    raw.shutdown();
                }
                conns.len()
            }
            Err(_) => 0,
        }
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// Begin draining without waiting: in-flight connections keep
    /// being served, new `Hello`s answer `Busy`, and the accept loop
    /// exits on its own once every connection has wound down (or the
    /// default grace window lapses).  Follow with
    /// [`ShardServerHandle::shutdown_graceful`] — or plain
    /// [`ShardServerHandle::shutdown`] — to join it.
    pub fn drain(&self) {
        self.control.begin_drain(DRAIN_GRACE);
    }

    /// Is the daemon currently draining?
    pub fn draining(&self) -> bool {
        self.control.draining()
    }

    /// Drain with an explicit grace window and wait for the accept
    /// loop to wind down; connections that outlive the window are
    /// severed on the way out.
    pub fn shutdown_graceful(mut self, grace: Duration) {
        self.control.begin_drain(grace);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
        self.kill_connections();
    }

    fn stop_and_join(&mut self) {
        self.control.stop.store(true, Ordering::Release);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for ShardServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Eager validation of an env spec string (bare id or mixture).
fn validate_spec(spec: &str) -> Result<()> {
    if spec.is_empty() {
        return Err(CairlError::Config("serve needs a non-empty env spec".into()));
    }
    if MixtureSpec::is_mixture(spec) {
        MixtureSpec::parse(spec).map(|_| ())
    } else {
        registry::validate(spec)
    }
}

/// The lane count a `Hello` for `spec` will reserve (what the builder
/// will produce: a mixture's summed lane counts, or the daemon's
/// configured default for a bare id).
fn requested_lanes(spec: &str, config: &ServeConfig) -> Result<usize> {
    if MixtureSpec::is_mixture(spec) {
        let parsed = MixtureSpec::parse(spec)?;
        Ok(parsed.entries().iter().map(|e| e.count).sum())
    } else {
        registry::validate(spec)?;
        Ok(config.lanes.max(1))
    }
}

/// Does `peer` pass the daemon's `--allow` list?  Empty list admits
/// everyone; Unix-socket peers (`"unix"`) are always admitted; a TCP
/// peer (`"ip:port"`, IPv6 as `"[addr]:port"`) must start with one of
/// the comma-separated prefixes **ending at a component boundary**: the
/// match must stop exactly where an octet, an IPv6 group or the port
/// does (`.`/`:`/`]`), so `--allow 10.0.1` admits `10.0.1.7:555` but
/// never `10.0.10.7:555`.
fn peer_allowed(allow: &str, peer: &str) -> bool {
    if allow.is_empty() || peer == "unix" {
        return true;
    }
    allow
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .any(|prefix| match peer.strip_prefix(prefix) {
            None => false,
            Some("") => true,
            Some(rest) => {
                prefix.ends_with(['.', ':', ']']) || rest.starts_with(['.', ':', ']'])
            }
        })
}

/// Poll-accept until stopped; each connection gets its own detached
/// thread, a stable id and a raw handle in the kill table.  Peers
/// failing the `--allow` list are dropped here, before a single frame
/// is read.  Idle polls back off exponentially (1 ms doubling to
/// [`ACCEPT_IDLE_CAP_MS`], reset on any accept) so an idle daemon
/// costs ~50 wakeups/s instead of 500.  While draining the loop keeps
/// accepting — a `Hello` during drain answers `Busy` in `serve_conn`
/// — and returns once the connection table empties or the grace
/// deadline lapses; `watch_sigterm` (the foreground path) additionally
/// turns a delivered SIGTERM into a [`DRAIN_GRACE`] drain.
fn accept_loop(
    listener: ShardListener,
    config: Arc<ServeConfig>,
    stats: Arc<ServerStats>,
    conns: Arc<ConnTable>,
    control: Arc<ServeControl>,
    watch_sigterm: bool,
) {
    let mut idle_ms = 1u64;
    loop {
        if control.stop.load(Ordering::Acquire) {
            return;
        }
        if watch_sigterm && TERM_FLAG.load(Ordering::SeqCst) && !control.draining() {
            eprintln!(
                "cairl serve: SIGTERM — draining (grace {}s)",
                DRAIN_GRACE.as_secs()
            );
            control.begin_drain(DRAIN_GRACE);
        }
        if control.draining() {
            let empty = conns.lock().map(|table| table.is_empty()).unwrap_or(true);
            if empty || control.drain_expired() {
                return;
            }
        }
        match listener.accept_nonblocking() {
            Ok(Some((stream, peer))) => {
                idle_ms = 1;
                if !peer_allowed(&config.allow, &peer) {
                    stats.note_rejected_peer();
                    eprintln!("cairl serve: rejected peer {peer} (not in --allow)");
                    drop(stream);
                    continue;
                }
                let id = stats.total_connections.fetch_add(1, Ordering::Relaxed) + 1;
                stats.m_connections.inc();
                if let Ok(raw) = stream.try_clone() {
                    if let Ok(mut table) = conns.lock() {
                        table.push((id, raw));
                    }
                }
                let config = Arc::clone(&config);
                let stats = Arc::clone(&stats);
                let conns = Arc::clone(&conns);
                let control = Arc::clone(&control);
                let _ = std::thread::Builder::new()
                    .name("cairl-shard-conn".into())
                    .spawn(move || {
                        serve_conn(stream, &config, &stats, id, &control);
                        stats.drop_client(id);
                        if let Ok(mut table) = conns.lock() {
                            table.retain(|(cid, _)| *cid != id);
                        }
                    });
            }
            Ok(None) | Err(_) => {
                std::thread::sleep(Duration::from_millis(idle_ms));
                idle_ms = (idle_ms * 2).min(ACCEPT_IDLE_CAP_MS);
            }
        }
    }
}

/// Best-effort error reply stamped with the offending request's seq
/// (or [`SEQ_NONE`] when no request seq is known); the connection
/// closes either way.
fn bail(stream: &mut FramedStream, seq: u32, message: &str) {
    let _ = stream.send(seq, MsgRef::Error { message });
}

/// Token check shared by `Hello` and `Status`.
fn authorized(config: &ServeConfig, token: &str) -> bool {
    config.token.is_empty() || token == config.token
}

/// Pack a padded `[n * padded]` observation buffer into its tail-elided
/// wire form: each lane's true observation back to back (protocol v4 —
/// padding never crosses the wire; the client re-pads).
fn pack_obs(obs: &[f32], padded: usize, widths: &[usize], packed: &mut [f32]) {
    let mut cursor = 0usize;
    for (i, &w) in widths.iter().enumerate() {
        packed[cursor..cursor + w].copy_from_slice(&obs[i * padded..i * padded + w]);
        cursor += w;
    }
}

/// One connection: handshake, then sequenced request/reply until
/// `Close`/EOF — or, with `--read-timeout` armed, until the peer goes
/// silent for longer than the deadline (the idle reaper).
fn serve_conn(
    stream: RawStream,
    config: &ServeConfig,
    stats: &ServerStats,
    id: u64,
    control: &ServeControl,
) {
    let Ok(mut stream) = FramedStream::new(stream) else {
        return;
    };
    if stream.set_deadlines(config.read_timeout, None).is_err() {
        return;
    }
    let mut host: Option<HostExec> = None;
    let mut seqs = SeqTracker::new();
    // Reusable step/reset buffers, sized at handshake.
    let mut obs: Vec<f32> = Vec::new();
    let mut transitions: Vec<Transition> = Vec::new();
    // Wire-form obs scratch: per-lane true widths and the tail-elided
    // block they pack into (`Σ widths` floats), sized at handshake.
    let mut padded = 0usize;
    let mut widths: Vec<usize> = Vec::new();
    let mut packed: Vec<f32> = Vec::new();

    loop {
        let (frame, decode_ns) = match stream.recv_timed() {
            Ok(pair) => pair,
            Err(CairlError::Io(_)) => return, // peer hung up
            // The read deadline fired: the peer sent nothing — not
            // even a Ping — for a whole window.  A timeout can strike
            // mid-frame, which loses framing, so the only safe move is
            // to close (releasing the client's lanes).
            Err(CairlError::DeadlineExceeded(_)) => return,
            Err(e) => {
                stats.note_bad_frame();
                bail(&mut stream, SEQ_NONE, &format!("bad frame: {e}"));
                return;
            }
        };
        if let Err(e) = seqs.accept(frame.seq) {
            stats.note_bad_frame();
            bail(&mut stream, SEQ_NONE, &e.to_string());
            return;
        }
        let seq = frame.seq;
        match frame.msg {
            Msg::Hello {
                spec,
                base_seed,
                first_lane,
                pipeline,
                token,
                wrap,
                ctx: _,
            } => {
                stats.note_request(id, 0);
                if !authorized(config, &token) {
                    stats.auth_failures.fetch_add(1, Ordering::Relaxed);
                    bail(&mut stream, seq, "unauthorized: bad or missing token");
                    return;
                }
                // A draining daemon serves what it already hosts but
                // takes no new work: every Hello answers Busy until
                // the drain completes.
                if control.draining() {
                    stats.busy_rejections.fetch_add(1, Ordering::Relaxed);
                    let busy = MsgRef::Busy {
                        active_lanes: stats.active_lanes() as u64,
                        max_lanes: config.max_lanes as u64,
                        retry_ms: BUSY_RETRY_MS,
                    };
                    if stream.send(seq, busy).is_err() {
                        return;
                    }
                    continue;
                }
                let spec = if spec.is_empty() {
                    config.env_spec.clone()
                } else {
                    spec
                };
                // An empty Hello.wrap defers to the daemon's configured
                // default chain; a non-empty one overrides it.
                let wrap = if wrap.is_empty() {
                    config.wrap.clone()
                } else {
                    wrap
                };
                let wrap_chain = match WrapperSpec::parse_chain(&wrap) {
                    Ok(chain) => chain,
                    Err(e) => {
                        bail(&mut stream, seq, &format!("bad wrap chain {wrap:?}: {e}"));
                        return;
                    }
                };
                // Admission control happens *before* the (expensive)
                // executor build: compute the lanes this Hello needs,
                // release any previous reservation (re-handshake), and
                // reserve against the budget.
                let lanes = match requested_lanes(&spec, config) {
                    Ok(lanes) => lanes,
                    Err(e) => {
                        bail(&mut stream, seq, &format!("cannot host {spec:?}: {e}"));
                        return;
                    }
                };
                stats.drop_client(id);
                host = None;
                if !stats.try_reserve(lanes) {
                    stats.busy_rejections.fetch_add(1, Ordering::Relaxed);
                    let busy = MsgRef::Busy {
                        active_lanes: stats.active_lanes() as u64,
                        max_lanes: config.max_lanes as u64,
                        retry_ms: BUSY_RETRY_MS,
                    };
                    if stream.send(seq, busy).is_err() {
                        return;
                    }
                    continue; // the client may retry its Hello
                }
                let threads = config.effective_threads();
                let built: Result<HostExec> = match config.kind {
                    // Keep the sync pool concrete so RandomRollout can
                    // run worker-side with the *global* lane streams.
                    ExecutorKind::PoolSync => build_env_pool_shard(
                        &spec,
                        config.lanes,
                        threads,
                        base_seed,
                        first_lane as usize,
                        config.kernel,
                        &wrap_chain,
                    )
                    .map(HostExec::Pool),
                    kind => build_executor_with_kernel(
                        &spec,
                        kind,
                        config.lanes,
                        threads,
                        base_seed + first_lane,
                        &wrap_chain,
                        config.kernel,
                    )
                    .map(HostExec::Boxed),
                };
                match built {
                    Ok(mut built) => {
                        let exec = built.exec();
                        exec.set_panic_policy(config.on_panic);
                        let n = exec.num_lanes();
                        if n != lanes {
                            // The builder's lane count wins — reconcile
                            // the admission reservation to match.
                            stats.release_lanes(lanes);
                            stats.active_lanes.fetch_add(n, Ordering::Relaxed);
                        }
                        let d = exec.obs_dim();
                        obs = vec![0.0f32; n * d];
                        transitions = vec![Transition::default(); n];
                        padded = d;
                        widths = exec.lane_specs().iter().map(|s| s.obs_dim).collect();
                        packed = vec![0.0f32; widths.iter().sum()];
                        // Register before replying: a client that probes
                        // `--status` right after its handshake must see
                        // itself in the table.
                        stats.register_client(id, &spec, n, pipeline);
                        stats.note_origin(&spec, &wrap, base_seed, first_lane);
                        if stream
                            .send(
                                seq,
                                MsgRef::Spec {
                                    obs_dim: d as u64,
                                    lane_specs: exec.lane_specs(),
                                },
                            )
                            .is_err()
                        {
                            stats.drop_client(id);
                            return;
                        }
                        // Chaos arms only now, after the Spec reply:
                        // the handshake always runs clean, and every
                        // (re)connection draws a fresh fault stream
                        // (its conn id), so a client that fails over
                        // never deterministically re-hits the same
                        // faults at the same replay points.
                        if let Some(profile) = &config.chaos {
                            if !profile.is_off() {
                                stream.set_fault_injector(Some(FaultPlan::new(profile, id)));
                            }
                        }
                        host = Some(built);
                    }
                    Err(e) => {
                        stats.release_lanes(lanes);
                        bail(&mut stream, seq, &format!("cannot host {spec:?}: {e}"));
                        return;
                    }
                }
            }
            Msg::Ping { nonce } => {
                // Liveness probe: valid at any point — before any
                // Hello, without a token (it leaks nothing but
                // liveness).  Echo the nonce back.
                stats.note_request(id, 0);
                if stream.send(seq, MsgRef::Pong { nonce }).is_err() {
                    return;
                }
            }
            Msg::Status { token } => {
                stats.note_request(id, 0);
                if !authorized(config, &token) {
                    stats.auth_failures.fetch_add(1, Ordering::Relaxed);
                    bail(&mut stream, seq, "unauthorized: bad or missing token");
                    return;
                }
                let report = stats.render_status();
                if stream
                    .send(seq, MsgRef::StatusReport { report: &report })
                    .is_err()
                {
                    return;
                }
            }
            Msg::Reset { ctx } => {
                stats.note_request(id, 0);
                let Some(host) = host.as_mut() else {
                    bail(&mut stream, seq, "Reset before Hello");
                    return;
                };
                let t0 = if ctx.is_none() { 0 } else { trace::now_ns() };
                let ok = catch_exec(|| host.exec().reset_into(&mut obs));
                if !ok {
                    bail(&mut stream, seq, "executor panicked during Reset");
                    return;
                }
                let timing = server_timing(ctx, decode_ns, t0);
                pack_obs(&obs, padded, &widths, &mut packed);
                if stream
                    .send(seq, MsgRef::Obs { obs: &packed, timing })
                    .is_err()
                {
                    return;
                }
            }
            Msg::Step { actions, ctx } => {
                stats.note_request(id, actions.len() as u64);
                let Some(host) = host.as_mut() else {
                    bail(&mut stream, seq, "Step before Hello");
                    return;
                };
                if actions.len() != transitions.len() {
                    bail(
                        &mut stream,
                        seq,
                        &format!(
                            "Step carried {} actions for {} lanes",
                            actions.len(),
                            transitions.len()
                        ),
                    );
                    return;
                }
                let t0 = if ctx.is_none() { 0 } else { trace::now_ns() };
                let ok =
                    catch_exec(|| host.exec().step_into(&actions, &mut obs, &mut transitions));
                if !ok {
                    bail(&mut stream, seq, "executor panicked during Step");
                    return;
                }
                let timing = server_timing(ctx, decode_ns, t0);
                pack_obs(&obs, padded, &widths, &mut packed);
                if stream
                    .send(
                        seq,
                        MsgRef::StepResult {
                            obs: &packed,
                            transitions: &transitions,
                            timing,
                        },
                    )
                    .is_err()
                {
                    return;
                }
            }
            Msg::RandomRollout { steps_per_lane, ctx } => {
                let Some(host) = host.as_mut() else {
                    stats.note_request(id, 0);
                    bail(&mut stream, seq, "RandomRollout before Hello");
                    return;
                };
                let t0 = if ctx.is_none() { 0 } else { trace::now_ns() };
                let mut counts = None;
                let ok = catch_exec(|| counts = host.random_rollout(steps_per_lane));
                if !ok {
                    stats.note_request(id, 0);
                    bail(&mut stream, seq, "executor panicked during RandomRollout");
                    return;
                }
                match counts {
                    Some(c) => {
                        stats.note_request(id, c.steps);
                        let timing = server_timing(ctx, decode_ns, t0);
                        if stream
                            .send(
                                seq,
                                MsgRef::RolloutDone {
                                    steps: c.steps,
                                    episodes: c.episodes,
                                    timing,
                                },
                            )
                            .is_err()
                        {
                            return;
                        }
                    }
                    None => {
                        stats.note_request(id, 0);
                        bail(
                            &mut stream,
                            seq,
                            "RandomRollout needs a pool-sync shard (serve --executor pool)",
                        );
                        return;
                    }
                }
            }
            Msg::Close => {
                stats.note_request(id, 0);
                return;
            }
            other => {
                stats.note_request(id, 0);
                bail(&mut stream, seq, &format!("unexpected message {other:?}"));
                return;
            }
        }
    }
}

/// Run an executor call, converting a panic (a poisoned pool) into a
/// clean `false` so the client gets an `Error` frame instead of EOF.
fn catch_exec(f: impl FnOnce()) -> bool {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).is_ok()
}

/// Close out a traced request: measure the executor window that opened
/// at `t0` (a [`trace::now_ns`] stamp taken just before the call) and
/// return the timing block the reply carries.  The client turns the
/// two durations into `decode`/`server_step` spans centered inside its
/// observed wire window — durations cross the wire, clocks never do.
/// An untraced request (context all zeros) reports zeros, and the hot
/// path pays nothing beyond the `is_none` branch.
fn server_timing(ctx: TraceCtx, decode_ns: u64, t0: u64) -> ServerTiming {
    if ctx.is_none() {
        return ServerTiming::default();
    }
    ServerTiming {
        decode_ns,
        step_ns: trace::now_ns().saturating_sub(t0),
    }
}

#[cfg(test)]
mod tests {
    use super::peer_allowed;

    #[test]
    fn allow_list_empty_and_unix_admit_everyone() {
        assert!(peer_allowed("", "10.0.10.7:555"));
        assert!(peer_allowed("10.0.1", "unix"));
        // Blank entries (stray commas/spaces) never admit anyone.
        assert!(!peer_allowed(" , ", "10.0.1.7:555"));
    }

    #[test]
    fn allow_list_stops_at_component_boundaries() {
        // An octet prefix admits only whole-component extensions...
        assert!(peer_allowed("10.0.1", "10.0.1.7:555"));
        assert!(peer_allowed("10.0.1", "10.0.1:555"));
        // ...never a longer octet that merely shares digits.
        assert!(!peer_allowed("10.0.1", "10.0.10.7:555"));
        assert!(!peer_allowed("10.0.1", "10.0.17.7:555"));
        // A trailing dot pins the boundary explicitly.
        assert!(peer_allowed("10.0.", "10.0.1.7:555"));
        assert!(!peer_allowed("10.0.", "10.10.1.7:555"));
        // A full ip admits any port; a full ip:port admits only itself.
        assert!(peer_allowed("127.0.0.1", "127.0.0.1:9000"));
        assert!(!peer_allowed("127.0.0.10", "127.0.0.1:9000"));
        assert!(peer_allowed("127.0.0.1:9000", "127.0.0.1:9000"));
        assert!(!peer_allowed("127.0.0.1:900", "127.0.0.1:9000"));
    }

    #[test]
    fn allow_list_handles_ipv6_literals() {
        // Bracketed literal: the `]` closes the address component.
        assert!(peer_allowed("[::1]", "[::1]:9000"));
        assert!(peer_allowed("[::1", "[::1]:9000"));
        assert!(!peer_allowed("[::1", "[::10]:9000"));
        assert!(peer_allowed("[2001:db8:", "[2001:db8::7]:555"));
        assert!(!peer_allowed("[2001:db8", "[2001:db80::7]:555"));
    }

    #[test]
    fn allow_list_is_comma_separated_any_match() {
        let allow = "127.0.0.1, 10.0.1";
        assert!(peer_allowed(allow, "127.0.0.1:4"));
        assert!(peer_allowed(allow, "10.0.1.9:4"));
        assert!(!peer_allowed(allow, "10.0.19.9:4"));
    }
}
