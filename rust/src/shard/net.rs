//! Shard transport: address grammar, listeners and framed streams.
//!
//! Two schemes cover the deployment shapes the service targets:
//!
//! * `unix:///path/to/shard.sock` — a Unix domain socket, the
//!   same-host default (lowest latency, filesystem-scoped access).
//! * `tcp://host:port` — TCP for cross-host shards (`port` may be `0`
//!   to let the OS pick; [`ShardListener::local_addr`] reports the
//!   bound address).  A bare `host:port` is accepted as TCP shorthand.
//!
//! [`FramedStream`] pairs a buffered reader and writer over one
//! connection and speaks [`proto`](crate::shard::proto) frames;
//! `TCP_NODELAY` is set on TCP streams because the protocol is strictly
//! request/reply — Nagle would serialise every batch behind a delayed
//! ACK.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;

use crate::core::error::{CairlError, Result};
use crate::shard::proto::{self, Frame, MsgRef};

fn err(msg: impl Into<String>) -> CairlError {
    CairlError::Shard(msg.into())
}

/// A parsed shard address.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardAddr {
    /// `unix://<path>`.
    #[cfg(unix)]
    Unix(PathBuf),
    /// `tcp://<host:port>` (or bare `host:port`).
    Tcp(String),
}

impl ShardAddr {
    /// Parse the address grammar above.
    pub fn parse(addr: &str) -> Result<ShardAddr> {
        let addr = addr.trim();
        if let Some(path) = addr.strip_prefix("unix://") {
            #[cfg(unix)]
            {
                if path.is_empty() {
                    return Err(err(format!("empty unix socket path in {addr:?}")));
                }
                return Ok(ShardAddr::Unix(PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            {
                return Err(err(format!(
                    "unix socket address {addr:?} is not supported on this platform"
                )));
            }
        }
        let host_port = addr.strip_prefix("tcp://").unwrap_or(addr);
        if host_port.contains("://") {
            return Err(err(format!(
                "unknown shard address scheme in {addr:?} (expected unix:// or tcp://)"
            )));
        }
        if !host_port.contains(':') {
            return Err(err(format!(
                "shard address {addr:?} needs host:port (or a unix:// path)"
            )));
        }
        Ok(ShardAddr::Tcp(host_port.to_string()))
    }

    /// Canonical textual form (what `--listen`/`--shard` accept).
    pub fn render(&self) -> String {
        match self {
            #[cfg(unix)]
            ShardAddr::Unix(path) => format!("unix://{}", path.display()),
            ShardAddr::Tcp(hp) => format!("tcp://{hp}"),
        }
    }
}

/// One accepted or dialed connection (Unix or TCP).
pub(crate) enum RawStream {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl RawStream {
    pub(crate) fn try_clone(&self) -> std::io::Result<RawStream> {
        Ok(match self {
            #[cfg(unix)]
            RawStream::Unix(s) => RawStream::Unix(s.try_clone()?),
            RawStream::Tcp(s) => RawStream::Tcp(s.try_clone()?),
        })
    }

    /// Force-close both directions of the connection.  Any blocked read
    /// on the peer (or on a clone of this stream) returns immediately —
    /// the server's kill switch for failover drills.
    pub(crate) fn shutdown(&self) {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            RawStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for RawStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.read(buf),
            RawStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for RawStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.write(buf),
            RawStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.flush(),
            RawStream::Tcp(s) => s.flush(),
        }
    }
}

/// A buffered, framed connection: [`send`](FramedStream::send) writes
/// one protocol frame and flushes, [`recv`](FramedStream::recv) blocks
/// for the next.
pub(crate) struct FramedStream {
    r: BufReader<RawStream>,
    w: BufWriter<RawStream>,
}

impl FramedStream {
    pub(crate) fn new(stream: RawStream) -> Result<FramedStream> {
        if let RawStream::Tcp(s) = &stream {
            // Request/reply over small frames: never wait out Nagle.
            let _ = s.set_nodelay(true);
        }
        let writer = stream.try_clone()?;
        Ok(FramedStream {
            r: BufReader::new(stream),
            w: BufWriter::new(writer),
        })
    }

    /// Dial a shard address.
    pub(crate) fn connect(addr: &ShardAddr) -> Result<FramedStream> {
        let stream = match addr {
            #[cfg(unix)]
            ShardAddr::Unix(path) => RawStream::Unix(UnixStream::connect(path).map_err(|e| {
                err(format!("connect {}: {e}", addr.render()))
            })?),
            ShardAddr::Tcp(hp) => RawStream::Tcp(TcpStream::connect(hp).map_err(|e| {
                err(format!("connect {}: {e}", addr.render()))
            })?),
        };
        FramedStream::new(stream)
    }

    /// Write one frame stamped with `seq` and flush it.
    pub(crate) fn send(&mut self, seq: u32, msg: MsgRef<'_>) -> Result<()> {
        proto::write_msg(&mut self.w, seq, msg)
    }

    /// Block for the next frame (sequence number + message).
    pub(crate) fn recv(&mut self) -> Result<Frame> {
        proto::read_msg(&mut self.r)
    }
}

/// A bound shard listener; nonblocking so the accept loop can poll a
/// shutdown flag.  Unix listeners own their socket file and remove it
/// (stale leftovers at bind, their own at drop).
pub(crate) enum ShardListener {
    #[cfg(unix)]
    Unix { listener: UnixListener, path: PathBuf },
    Tcp(TcpListener),
}

impl ShardListener {
    pub(crate) fn bind(addr: &ShardAddr) -> Result<ShardListener> {
        match addr {
            #[cfg(unix)]
            ShardAddr::Unix(path) => {
                // A dead daemon leaves its socket file behind; binding
                // over it is the restart path.
                let _ = std::fs::remove_file(path);
                let listener = UnixListener::bind(path)
                    .map_err(|e| err(format!("bind {}: {e}", addr.render())))?;
                listener.set_nonblocking(true)?;
                Ok(ShardListener::Unix {
                    listener,
                    path: path.clone(),
                })
            }
            ShardAddr::Tcp(hp) => {
                let listener = TcpListener::bind(hp)
                    .map_err(|e| err(format!("bind {}: {e}", addr.render())))?;
                listener.set_nonblocking(true)?;
                Ok(ShardListener::Tcp(listener))
            }
        }
    }

    /// The bound address in canonical form (TCP reports the real port,
    /// so `tcp://127.0.0.1:0` comes back dialable).
    pub(crate) fn local_addr(&self) -> String {
        match self {
            #[cfg(unix)]
            ShardListener::Unix { path, .. } => format!("unix://{}", path.display()),
            ShardListener::Tcp(listener) => match listener.local_addr() {
                Ok(a) => format!("tcp://{a}"),
                Err(_) => "tcp://<unknown>".to_string(),
            },
        }
    }

    /// One nonblocking accept: `Ok(None)` when no client is waiting.
    /// A connection comes back with the peer's address (`"ip:port"` for
    /// TCP, `"unix"` for a Unix socket — filesystem permissions already
    /// scope those) for the daemon's `--allow` check.
    pub(crate) fn accept_nonblocking(&self) -> std::io::Result<Option<(RawStream, String)>> {
        match self {
            #[cfg(unix)]
            ShardListener::Unix { listener, .. } => match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    Ok(Some((RawStream::Unix(stream), "unix".to_string())))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
            ShardListener::Tcp(listener) => match listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(false)?;
                    Ok(Some((RawStream::Tcp(stream), peer.to_string())))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
        }
    }
}

#[cfg(unix)]
impl Drop for ShardListener {
    fn drop(&mut self) {
        if let ShardListener::Unix { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_grammar_parses_and_renders() {
        #[cfg(unix)]
        {
            let a = ShardAddr::parse("unix:///tmp/s0.sock").unwrap();
            assert_eq!(a, ShardAddr::Unix(PathBuf::from("/tmp/s0.sock")));
            assert_eq!(a.render(), "unix:///tmp/s0.sock");
        }
        let t = ShardAddr::parse("tcp://127.0.0.1:7000").unwrap();
        assert_eq!(t, ShardAddr::Tcp("127.0.0.1:7000".into()));
        assert_eq!(t.render(), "tcp://127.0.0.1:7000");
        // Bare host:port is TCP shorthand.
        assert_eq!(
            ShardAddr::parse("localhost:7000").unwrap(),
            ShardAddr::Tcp("localhost:7000".into())
        );
        for bad in ["", "unix://", "quic://x:1", "justahost"] {
            assert!(ShardAddr::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }
}
