//! Shard transport: address grammar, listeners and framed streams.
//!
//! Two schemes cover the deployment shapes the service targets:
//!
//! * `unix:///path/to/shard.sock` — a Unix domain socket, the
//!   same-host default (lowest latency, filesystem-scoped access).
//! * `tcp://host:port` — TCP for cross-host shards (`port` may be `0`
//!   to let the OS pick; [`ShardListener::local_addr`] reports the
//!   bound address).  A bare `host:port` is accepted as TCP shorthand.
//!
//! [`FramedStream`] pairs a buffered reader and writer over one
//! connection and speaks [`proto`](crate::shard::proto) frames;
//! `TCP_NODELAY` is set on TCP streams because the protocol is strictly
//! request/reply — Nagle would serialise every batch behind a delayed
//! ACK.
//!
//! **Deadlines.**  [`FramedStream::set_deadlines`] arms per-frame
//! read/write timeouts on the underlying socket; an elapsed deadline
//! surfaces as [`CairlError::DeadlineExceeded`] and counts into
//! `cairl_deadline_timeouts_total`.  A timeout can fire mid-frame, at
//! which point the stream's framing position is lost — so a deadline is
//! always **fatal to the connection**: callers must close (and, on the
//! client, fail over), never retry the read.
//!
//! **Chaos.**  [`FramedStream::set_fault_injector`] attaches a
//! seed-driven [`FaultPlan`](crate::faults::FaultPlan); each `send`
//! consults it and may corrupt a byte, truncate the frame, delay, or
//! reset the connection — the deterministic fault surface the chaos
//! tests and `--chaos` profiles drive.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::time::Duration;

use crate::core::error::{CairlError, Result};
use crate::faults::{FaultPlan, WireFault};
use crate::shard::proto::{self, Frame, MsgRef};

fn err(msg: impl Into<String>) -> CairlError {
    CairlError::Shard(msg.into())
}

/// A parsed shard address.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardAddr {
    /// `unix://<path>`.
    #[cfg(unix)]
    Unix(PathBuf),
    /// `tcp://<host:port>` (or bare `host:port`).
    Tcp(String),
}

impl ShardAddr {
    /// Parse the address grammar above.
    pub fn parse(addr: &str) -> Result<ShardAddr> {
        let addr = addr.trim();
        if let Some(path) = addr.strip_prefix("unix://") {
            #[cfg(unix)]
            {
                if path.is_empty() {
                    return Err(err(format!("empty unix socket path in {addr:?}")));
                }
                return Ok(ShardAddr::Unix(PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            {
                return Err(err(format!(
                    "unix socket address {addr:?} is not supported on this platform"
                )));
            }
        }
        let host_port = addr.strip_prefix("tcp://").unwrap_or(addr);
        if host_port.contains("://") {
            return Err(err(format!(
                "unknown shard address scheme in {addr:?} (expected unix:// or tcp://)"
            )));
        }
        if !host_port.contains(':') {
            return Err(err(format!(
                "shard address {addr:?} needs host:port (or a unix:// path)"
            )));
        }
        Ok(ShardAddr::Tcp(host_port.to_string()))
    }

    /// Canonical textual form (what `--listen`/`--shard` accept).
    pub fn render(&self) -> String {
        match self {
            #[cfg(unix)]
            ShardAddr::Unix(path) => format!("unix://{}", path.display()),
            ShardAddr::Tcp(hp) => format!("tcp://{hp}"),
        }
    }
}

/// One accepted or dialed connection (Unix or TCP).
pub(crate) enum RawStream {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl RawStream {
    pub(crate) fn try_clone(&self) -> std::io::Result<RawStream> {
        Ok(match self {
            #[cfg(unix)]
            RawStream::Unix(s) => RawStream::Unix(s.try_clone()?),
            RawStream::Tcp(s) => RawStream::Tcp(s.try_clone()?),
        })
    }

    /// Force-close both directions of the connection.  Any blocked read
    /// on the peer (or on a clone of this stream) returns immediately —
    /// the server's kill switch for failover drills.
    pub(crate) fn shutdown(&self) {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            RawStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    /// Arm (or clear, with `None`) the socket's receive timeout.
    pub(crate) fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.set_read_timeout(d),
            RawStream::Tcp(s) => s.set_read_timeout(d),
        }
    }

    /// Arm (or clear, with `None`) the socket's send timeout.
    pub(crate) fn set_write_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.set_write_timeout(d),
            RawStream::Tcp(s) => s.set_write_timeout(d),
        }
    }
}

/// Rewrite a timed-out I/O error (`WouldBlock`/`TimedOut` is how a
/// socket timeout surfaces) as [`CairlError::DeadlineExceeded`], and
/// count it.  Everything else passes through unchanged.
fn map_deadline<T>(res: Result<T>, dir: &str) -> Result<T> {
    match res {
        Err(CairlError::Io(e))
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            crate::telemetry::counter("cairl_deadline_timeouts_total").inc();
            Err(CairlError::DeadlineExceeded(format!(
                "{dir} deadline elapsed: {e}"
            )))
        }
        other => other,
    }
}

impl Read for RawStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.read(buf),
            RawStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for RawStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.write(buf),
            RawStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            RawStream::Unix(s) => s.flush(),
            RawStream::Tcp(s) => s.flush(),
        }
    }
}

/// A buffered, framed connection: [`send`](FramedStream::send) writes
/// one protocol frame and flushes, [`recv`](FramedStream::recv) blocks
/// for the next.
pub(crate) struct FramedStream {
    r: BufReader<RawStream>,
    w: BufWriter<RawStream>,
    faults: Option<FaultPlan>,
}

impl FramedStream {
    pub(crate) fn new(stream: RawStream) -> Result<FramedStream> {
        if let RawStream::Tcp(s) = &stream {
            // Request/reply over small frames: never wait out Nagle.
            let _ = s.set_nodelay(true);
        }
        let writer = stream.try_clone()?;
        Ok(FramedStream {
            r: BufReader::new(stream),
            w: BufWriter::new(writer),
            faults: None,
        })
    }

    /// Dial a shard address.
    pub(crate) fn connect(addr: &ShardAddr) -> Result<FramedStream> {
        let stream = match addr {
            #[cfg(unix)]
            ShardAddr::Unix(path) => RawStream::Unix(UnixStream::connect(path).map_err(|e| {
                err(format!("connect {}: {e}", addr.render()))
            })?),
            ShardAddr::Tcp(hp) => RawStream::Tcp(TcpStream::connect(hp).map_err(|e| {
                err(format!("connect {}: {e}", addr.render()))
            })?),
        };
        FramedStream::new(stream)
    }

    /// Arm (or clear) per-frame read/write deadlines on the underlying
    /// socket.  An elapsed deadline surfaces from `send`/`recv` as
    /// [`CairlError::DeadlineExceeded`] and is fatal to the connection
    /// (a timeout can strike mid-frame, losing framing) — close and,
    /// client-side, fail over.
    pub(crate) fn set_deadlines(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<()> {
        self.r.get_ref().set_read_timeout(read)?;
        self.w.get_ref().set_write_timeout(write)?;
        Ok(())
    }

    /// Attach a seed-driven fault injector consulted on every `send`.
    /// Attach only **after** the handshake so connects and failover
    /// re-dials always succeed; `None` detaches.
    pub(crate) fn set_fault_injector(&mut self, plan: Option<FaultPlan>) {
        self.faults = plan;
    }

    /// Force-close the connection under the buffers (both halves share
    /// the socket) — the injector's reset/truncate kill switch.
    fn force_shutdown(&mut self) {
        self.r.get_ref().shutdown();
    }

    /// Write one frame stamped with `seq` and flush it.
    pub(crate) fn send(&mut self, seq: u32, msg: MsgRef<'_>) -> Result<()> {
        let fault = self.faults.as_mut().and_then(|p| p.next_wire_fault());
        match fault {
            None => map_deadline(proto::write_msg(&mut self.w, seq, msg), "send"),
            Some(WireFault::Delay(d)) => {
                std::thread::sleep(d);
                map_deadline(proto::write_msg(&mut self.w, seq, msg), "send")
            }
            Some(WireFault::Corrupt { offset, mask }) => {
                let mut frame = proto::encode(seq, msg);
                let i = (offset % frame.len() as u64) as usize;
                frame[i] ^= mask;
                let res = self
                    .w
                    .write_all(&frame)
                    .and_then(|_| self.w.flush())
                    .map_err(CairlError::from);
                map_deadline(res, "send")
            }
            Some(WireFault::Truncate { keep }) => {
                let frame = proto::encode(seq, msg);
                let max_keep = frame.len().saturating_sub(1).max(1);
                let keep = 1 + (keep as usize % max_keep);
                let _ = self.w.write_all(&frame[..keep]);
                let _ = self.w.flush();
                self.force_shutdown();
                Err(CairlError::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "chaos: frame truncated mid-send",
                )))
            }
            Some(WireFault::Reset) => {
                self.force_shutdown();
                Err(CairlError::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "chaos: connection reset",
                )))
            }
        }
    }

    /// Block for the next frame (sequence number + message).
    pub(crate) fn recv(&mut self) -> Result<Frame> {
        map_deadline(proto::read_msg(&mut self.r), "recv")
    }

    /// Block for the next frame and also report the nanoseconds spent
    /// decoding its payload (checksum verify + parse, once the bytes
    /// are in memory).  The server's `decode` span source: wire wait is
    /// excluded, so the duration travels back in
    /// [`ServerTiming`](crate::shard::proto::ServerTiming) without
    /// needing a cross-host clock.
    pub(crate) fn recv_timed(&mut self) -> Result<(Frame, u64)> {
        map_deadline(proto::read_msg_timed(&mut self.r), "recv")
    }
}

/// A bound shard listener; nonblocking so the accept loop can poll a
/// shutdown flag.  Unix listeners own their socket file and remove it
/// (stale leftovers at bind, their own at drop).
pub(crate) enum ShardListener {
    #[cfg(unix)]
    Unix { listener: UnixListener, path: PathBuf },
    Tcp(TcpListener),
}

impl ShardListener {
    pub(crate) fn bind(addr: &ShardAddr) -> Result<ShardListener> {
        match addr {
            #[cfg(unix)]
            ShardAddr::Unix(path) => {
                // A dead daemon leaves its socket file behind; binding
                // over it is the restart path.
                let _ = std::fs::remove_file(path);
                let listener = UnixListener::bind(path)
                    .map_err(|e| err(format!("bind {}: {e}", addr.render())))?;
                listener.set_nonblocking(true)?;
                Ok(ShardListener::Unix {
                    listener,
                    path: path.clone(),
                })
            }
            ShardAddr::Tcp(hp) => {
                let listener = TcpListener::bind(hp)
                    .map_err(|e| err(format!("bind {}: {e}", addr.render())))?;
                listener.set_nonblocking(true)?;
                Ok(ShardListener::Tcp(listener))
            }
        }
    }

    /// The bound address in canonical form (TCP reports the real port,
    /// so `tcp://127.0.0.1:0` comes back dialable).
    pub(crate) fn local_addr(&self) -> String {
        match self {
            #[cfg(unix)]
            ShardListener::Unix { path, .. } => format!("unix://{}", path.display()),
            ShardListener::Tcp(listener) => match listener.local_addr() {
                Ok(a) => format!("tcp://{a}"),
                Err(_) => "tcp://<unknown>".to_string(),
            },
        }
    }

    /// One nonblocking accept: `Ok(None)` when no client is waiting.
    /// A connection comes back with the peer's address (`"ip:port"` for
    /// TCP, `"unix"` for a Unix socket — filesystem permissions already
    /// scope those) for the daemon's `--allow` check.
    pub(crate) fn accept_nonblocking(&self) -> std::io::Result<Option<(RawStream, String)>> {
        match self {
            #[cfg(unix)]
            ShardListener::Unix { listener, .. } => match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    Ok(Some((RawStream::Unix(stream), "unix".to_string())))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
            ShardListener::Tcp(listener) => match listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(false)?;
                    Ok(Some((RawStream::Tcp(stream), peer.to_string())))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
        }
    }
}

#[cfg(unix)]
impl Drop for ShardListener {
    fn drop(&mut self) {
        if let ShardListener::Unix { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_grammar_parses_and_renders() {
        #[cfg(unix)]
        {
            let a = ShardAddr::parse("unix:///tmp/s0.sock").unwrap();
            assert_eq!(a, ShardAddr::Unix(PathBuf::from("/tmp/s0.sock")));
            assert_eq!(a.render(), "unix:///tmp/s0.sock");
        }
        let t = ShardAddr::parse("tcp://127.0.0.1:7000").unwrap();
        assert_eq!(t, ShardAddr::Tcp("127.0.0.1:7000".into()));
        assert_eq!(t.render(), "tcp://127.0.0.1:7000");
        // Bare host:port is TCP shorthand.
        assert_eq!(
            ShardAddr::parse("localhost:7000").unwrap(),
            ShardAddr::Tcp("localhost:7000".into())
        );
        for bad in ["", "unix://", "quic://x:1", "justahost"] {
            assert!(ShardAddr::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }
}
