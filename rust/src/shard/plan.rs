//! Cost-aware shard placement: which lanes live on which shard.
//!
//! A mixture's components can differ in step cost by orders of
//! magnitude (a fused CartPole lane vs a `GridRTS-v0` match), so
//! splitting lanes *evenly* across shards leaves the cheap shard idle
//! while the expensive one drags every lockstep batch.  [`ShardPlan`]
//! instead balances **measured cost**: a quick calibration rollout
//! ([`calibrate_costs`]) times one env per distinct component id, and
//! the planner cuts the lane list where the *cumulative cost* crosses
//! each shard's fair share — `CartPole-v1:32,GridRTS-v0:4` lands ~34
//! cheap lanes on one shard and ~2 expensive ones on the other rather
//! than 18/18.
//!
//! Placement is **contiguous in global lane order**: shard `s` owns
//! lanes `[first_lane, first_lane + lanes)`.  That is what preserves
//! the determinism contract — the shard seeds local lane `j` with
//! `base_seed + first_lane + j`, exactly the seed the same lane holds
//! in a local pool, so sharded trajectories are bit-identical to local
//! ones (`rust/tests/shard_pool.rs` pins it).  The placement tests
//! assert on the plan itself, never on wall clock.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::coordinator::registry::{self, MixtureEntry};
use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::wrappers::apply_wrappers;

/// Steps timed per distinct component id by [`calibrate_costs`] — small
/// enough to be invisible at connect time, large enough to average out
/// the reset transient.
pub const CALIBRATION_STEPS: u64 = 128;

/// One shard's slice of the global lane list.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardAssignment {
    /// Sub-mixture hosted by this shard, in lane order (component
    /// wrapper chains included).
    pub entries: Vec<MixtureEntry>,
    /// First global lane index of the slice.
    pub first_lane: usize,
    /// Number of lanes on this shard.
    pub lanes: usize,
    /// Modelled cost share (sum of the slice's per-lane costs).
    pub cost: f64,
}

impl ShardAssignment {
    /// Render the sub-mixture as a spec string (`"id[+chain]:count,..."`)
    /// — the `Hello` payload the client sends this shard.  Component
    /// wrapper chains ride along in the label, so the daemon rebuilds
    /// exactly the lane groups a local pool would.
    pub fn spec(&self) -> String {
        self.entries
            .iter()
            .map(|e| format!("{}:{}", e.label(), e.count))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// A complete placement: one [`ShardAssignment`] per shard, covering
/// every global lane exactly once, in order.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardPlan {
    assignments: Vec<ShardAssignment>,
}

impl ShardPlan {
    /// Plan `entries` (the flattened mixture components, spec order)
    /// across `shards` shards using per-component step `costs` (keyed
    /// by [`MixtureEntry::label`]; seconds per step, or any consistent
    /// unit; labels missing from the map count 1.0).  Boundaries fall
    /// where cumulative cost crosses each shard's fair share of the
    /// total, clamped so every shard gets at least one lane.
    pub fn plan(
        entries: &[MixtureEntry],
        shards: usize,
        costs: &BTreeMap<String, f64>,
    ) -> Result<ShardPlan> {
        let n: usize = entries.iter().map(|e| e.count).sum();
        if shards == 0 {
            return Err(CairlError::Config("a shard plan needs at least one shard".into()));
        }
        if n == 0 {
            return Err(CairlError::Config("a shard plan needs at least one lane".into()));
        }
        if shards > n {
            return Err(CairlError::Config(format!(
                "cannot place {n} lanes on {shards} shards (every shard needs a lane)"
            )));
        }

        // Per-lane cost in lane order; prefix[i] = cost of lanes [0, i).
        let mut lane_cost = Vec::with_capacity(n);
        for entry in entries {
            let c = costs.get(&entry.label()).copied().unwrap_or(1.0).max(1e-12);
            lane_cost.extend(std::iter::repeat(c).take(entry.count));
        }
        let mut prefix = Vec::with_capacity(n + 1);
        let mut acc = 0.0f64;
        prefix.push(0.0);
        for &c in &lane_cost {
            acc += c;
            prefix.push(acc);
        }
        let total = acc;

        // Cut lane boundaries at the fair-share crossings.
        let mut cuts = Vec::with_capacity(shards + 1);
        cuts.push(0usize);
        let mut prev = 0usize;
        for s in 0..shards {
            let cut = if s == shards - 1 {
                n
            } else {
                let target = total * (s + 1) as f64 / shards as f64;
                let mut idx = prev + 1;
                while idx < n && prefix[idx] < target {
                    idx += 1;
                }
                // Leave one lane for each remaining shard.
                idx.min(n - (shards - 1 - s))
            };
            cuts.push(cut);
            prev = cut;
        }

        // Slice the component list along the cuts.
        let mut assignments = Vec::with_capacity(shards);
        let mut component = 0usize; // index into entries
        let mut used = 0usize; // lanes of entries[component] already placed
        for s in 0..shards {
            let (start, end) = (cuts[s], cuts[s + 1]);
            let mut remaining = end - start;
            let mut sub: Vec<MixtureEntry> = Vec::new();
            while remaining > 0 {
                let entry = &entries[component];
                let available = entry.count - used;
                let take = available.min(remaining);
                sub.push(MixtureEntry {
                    spec: entry.spec.clone(),
                    count: take,
                    wrappers: entry.wrappers.clone(),
                });
                used += take;
                remaining -= take;
                if used == entry.count {
                    component += 1;
                    used = 0;
                }
            }
            assignments.push(ShardAssignment {
                entries: sub,
                first_lane: start,
                lanes: end - start,
                cost: prefix[end] - prefix[start],
            });
        }
        Ok(ShardPlan { assignments })
    }

    /// The per-shard assignments, shard order (= global lane order).
    pub fn assignments(&self) -> &[ShardAssignment] {
        &self.assignments
    }

    /// Total lanes across every shard.
    pub fn total_lanes(&self) -> usize {
        self.assignments.iter().map(|a| a.lanes).sum()
    }

    /// Human-readable one-liner per shard (CLI/bench logging).
    pub fn describe(&self) -> String {
        self.assignments
            .iter()
            .enumerate()
            .map(|(s, a)| {
                format!(
                    "shard {s}: lanes {}..{} ({}, cost {:.3})",
                    a.first_lane,
                    a.first_lane + a.lanes,
                    a.spec(),
                    a.cost
                )
            })
            .collect::<Vec<_>>()
            .join("; ")
    }
}

/// Measure per-step wall-clock cost for every distinct component
/// (keyed by [`MixtureEntry::label`], so a wrapped variant is costed
/// with its chain applied): one env per label, seeded and reset,
/// [`CALIBRATION_STEPS`] uniform-random steps timed.  Wall-clock is
/// inherently noisy — the plan built on it is best-effort load
/// balancing, while correctness (bit-determinism) never depends on
/// where a lane landed.
pub fn calibrate_costs(entries: &[MixtureEntry]) -> Result<BTreeMap<String, f64>> {
    let mut costs = BTreeMap::new();
    for entry in entries {
        let id = entry.label();
        if costs.contains_key(&id) {
            continue;
        }
        let mut env = apply_wrappers(registry::make(&entry.spec)?, &entry.wrappers);
        let space = env.action_space();
        let mut obs = vec![0.0f32; env.obs_dim()];
        let mut rng = Pcg32::new(0xca11b, 17);
        env.seed(0);
        env.reset_into(&mut obs);
        let start = Instant::now();
        for _ in 0..CALIBRATION_STEPS {
            let a = space.sample(&mut rng);
            let t = env.step_into(&a, &mut obs);
            if t.done || t.truncated {
                env.reset_into(&mut obs);
            }
        }
        let secs = start.elapsed().as_secs_f64().max(1e-9);
        costs.insert(id.clone(), secs / CALIBRATION_STEPS as f64);
    }
    Ok(costs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(id, c)| (id.to_string(), *c)).collect()
    }

    fn entries(pairs: &[(&str, usize)]) -> Vec<MixtureEntry> {
        pairs.iter().map(|(id, n)| MixtureEntry::bare(id, *n)).collect()
    }

    #[test]
    fn uniform_costs_split_evenly() {
        let plan = ShardPlan::plan(
            &entries(&[("CartPole-v1", 8)]),
            2,
            &costs(&[("CartPole-v1", 1.0)]),
        )
        .unwrap();
        let a = plan.assignments();
        assert_eq!(a.len(), 2);
        assert_eq!((a[0].first_lane, a[0].lanes), (0, 4));
        assert_eq!((a[1].first_lane, a[1].lanes), (4, 4));
        assert_eq!(a[0].spec(), "CartPole-v1:4");
        assert_eq!(plan.total_lanes(), 8);
    }

    #[test]
    fn expensive_components_pull_the_boundary() {
        // 32 cheap + 4 expensive lanes: the cost-aware cut lands far
        // from the even 18/18 split.
        let plan = ShardPlan::plan(
            &entries(&[("CartPole-v1", 32), ("GridRTS-v0", 4)]),
            2,
            &costs(&[("CartPole-v1", 1.0), ("GridRTS-v0", 50.0)]),
        )
        .unwrap();
        let a = plan.assignments();
        assert_eq!(a[0].lanes + a[1].lanes, 36);
        assert_ne!(a[0].lanes, 18, "placement must not be an even lane split");
        // The first shard absorbs all the cheap lanes plus a slice of
        // the expensive ones; the costs end up near parity.
        assert!(a[0].lanes > 30, "cheap shard got {} lanes", a[0].lanes);
        let ratio = a[0].cost / a[1].cost;
        assert!((0.4..2.5).contains(&ratio), "cost ratio {ratio}");
    }

    #[test]
    fn every_shard_gets_at_least_one_lane() {
        // One component so expensive it would swallow every target: the
        // clamp still leaves a lane for the last shard.
        let plan = ShardPlan::plan(
            &entries(&[("GridRTS-v0", 2), ("CartPole-v1", 1)]),
            3,
            &costs(&[("GridRTS-v0", 1000.0), ("CartPole-v1", 1.0)]),
        )
        .unwrap();
        for a in plan.assignments() {
            assert!(a.lanes >= 1);
        }
        assert_eq!(plan.total_lanes(), 3);
    }

    #[test]
    fn degenerate_plans_error() {
        let e = entries(&[("CartPole-v1", 2)]);
        let c = costs(&[]);
        assert!(ShardPlan::plan(&e, 0, &c).is_err());
        assert!(ShardPlan::plan(&e, 3, &c).is_err());
        assert!(ShardPlan::plan(&[], 1, &c).is_err());
    }

    #[test]
    fn sub_specs_cover_the_mixture_in_order() {
        let plan = ShardPlan::plan(
            &entries(&[("A-v0", 3), ("B-v0", 3)]),
            2,
            &costs(&[("A-v0", 1.0), ("B-v0", 1.0)]),
        )
        .unwrap();
        let a = plan.assignments();
        assert_eq!(a[0].spec(), "A-v0:3");
        assert_eq!(a[1].spec(), "B-v0:3");
        // A cut inside a component splits it across both sub-specs.
        let skew = ShardPlan::plan(
            &entries(&[("A-v0", 3), ("B-v0", 3)]),
            2,
            &costs(&[("A-v0", 10.0), ("B-v0", 1.0)]),
        )
        .unwrap();
        assert_eq!(skew.assignments()[0].spec(), "A-v0:2");
        assert_eq!(skew.assignments()[1].spec(), "A-v0:1,B-v0:3");
    }

    #[test]
    fn calibration_measures_every_distinct_id() {
        let costs =
            calibrate_costs(&entries(&[("CartPole-v1", 4), ("MountainCar-v0", 2)])).unwrap();
        assert_eq!(costs.len(), 2);
        assert!(costs.values().all(|&c| c > 0.0));
        assert!(calibrate_costs(&entries(&[("NoSuchEnv-v0", 1)])).is_err());
    }
}
