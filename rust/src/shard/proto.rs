//! The shard wire protocol: compact length-prefixed binary frames.
//!
//! Every message travels as one frame:
//!
//! ```text
//! [len: u32 LE] [payload: len bytes]
//! payload = [version: u8] [tag: u8] [body ...] [checksum: u32 LE]
//! ```
//!
//! `len` counts the payload (version through checksum).  The checksum is
//! FNV-1a/32 over `version..body`, so a flipped bit anywhere in a frame
//! is rejected before the body is even parsed.  Frames larger than
//! [`MAX_FRAME`] are refused outright — a corrupt length prefix can
//! never drive a gigabyte allocation.
//!
//! Decoding is **total**: every read is bounds-checked and every invalid
//! input (truncated body, bad tag, bad bool, non-UTF-8 string, trailing
//! garbage, checksum mismatch) returns [`CairlError::Shard`] — the
//! decoder never panics, which `rust/tests/shard_pool.rs` fuzzes.
//!
//! The message set mirrors the [`BatchedExecutor`]
//! (crate::coordinator::pool::BatchedExecutor) surface: a `Hello`
//! handshake answered by `Spec` (reusing [`LaneSpec`] so the client sees
//! exactly the metadata a local pool would report), `Reset`/`Obs`,
//! `Step`/`StepResult` with f32 observation payloads, a whole-workload
//! `RandomRollout`/`RolloutDone` pair (the free-running throughput mode
//! crosses the wire **once**), `Close` and `Error`.
//!
//! Two enums, one format: [`MsgRef`] borrows its payloads for
//! allocation-light encoding on the hot path, [`Msg`] owns them for
//! decoding; `decode(encode(m))` round-trips every message.

use std::io::{Read, Write};

use crate::coordinator::pool::LaneSpec;
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::core::spaces::{Action, Space};

/// Protocol revision; bumped on any wire-format change.  A frame whose
/// version byte differs is rejected at decode.
pub const PROTO_VERSION: u8 = 1;

/// Hard ceiling on payload length (64 MiB) — refuse corrupt length
/// prefixes before allocating.
pub const MAX_FRAME: usize = 1 << 26;

const TAG_HELLO: u8 = 1;
const TAG_SPEC: u8 = 2;
const TAG_RESET: u8 = 3;
const TAG_OBS: u8 = 4;
const TAG_STEP: u8 = 5;
const TAG_STEP_RESULT: u8 = 6;
const TAG_RANDOM_ROLLOUT: u8 = 7;
const TAG_ROLLOUT_DONE: u8 = 8;
const TAG_CLOSE: u8 = 9;
const TAG_ERROR: u8 = 10;

/// An outbound message, borrowing its payloads (no clone to send a
/// `&[Action]` or an observation buffer).
#[derive(Clone, Copy, Debug)]
pub enum MsgRef<'a> {
    /// Client handshake: the env spec the shard should host (empty =
    /// the daemon's configured default), the pool-wide base seed and
    /// this shard's first global lane.  The shard seeds local lane `j`
    /// with `base_seed + first_lane + j`, so a sharded pool's lanes hold
    /// exactly the RNG streams of the equivalent local pool.
    Hello {
        spec: &'a str,
        base_seed: u64,
        first_lane: u64,
    },
    /// Server handshake reply: the hosted executor's padded width and
    /// per-lane metadata (shard-local offsets).
    Spec {
        obs_dim: u64,
        lane_specs: &'a [LaneSpec],
    },
    /// Reset every lane; answered by [`MsgRef::Obs`].
    Reset,
    /// A `[lanes * obs_dim]` observation block (shard-local padding).
    Obs { obs: &'a [f32] },
    /// One lockstep batch of actions, lane order; answered by
    /// [`MsgRef::StepResult`].
    Step { actions: &'a [Action] },
    /// Batch step reply: the observation block plus per-lane transitions.
    StepResult {
        obs: &'a [f32],
        transitions: &'a [Transition],
    },
    /// Run a whole free-running random rollout shard-side; answered by
    /// [`MsgRef::RolloutDone`].
    RandomRollout { steps_per_lane: u64 },
    /// Aggregate counts of a completed shard-side rollout.
    RolloutDone { steps: u64, episodes: u64 },
    /// Orderly hang-up.
    Close,
    /// Server-side failure (bad spec, wrong action count, executor
    /// panic); the connection closes after this frame.
    Error { message: &'a str },
}

/// A decoded (owned) message; the receive-side mirror of [`MsgRef`].
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    Hello {
        spec: String,
        base_seed: u64,
        first_lane: u64,
    },
    Spec {
        obs_dim: u64,
        lane_specs: Vec<LaneSpec>,
    },
    Reset,
    Obs {
        obs: Vec<f32>,
    },
    Step {
        actions: Vec<Action>,
    },
    StepResult {
        obs: Vec<f32>,
        transitions: Vec<Transition>,
    },
    RandomRollout {
        steps_per_lane: u64,
    },
    RolloutDone {
        steps: u64,
        episodes: u64,
    },
    Close,
    Error {
        message: String,
    },
}

fn err(msg: impl Into<String>) -> CairlError {
    CairlError::Shard(msg.into())
}

/// FNV-1a/32 over a byte slice — the frame checksum.
fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        put_f32(out, x);
    }
}

fn put_space(out: &mut Vec<u8>, space: &Space) {
    match space {
        Space::Discrete { n } => {
            out.push(0);
            put_u64(out, *n as u64);
        }
        Space::Box { low, high, shape } => {
            out.push(1);
            put_f32s(out, low);
            put_f32s(out, high);
            put_u32(out, shape.len() as u32);
            for &d in shape {
                put_u64(out, d as u64);
            }
        }
    }
}

fn put_action(out: &mut Vec<u8>, action: &Action) {
    match action {
        Action::Discrete(i) => {
            out.push(0);
            put_u64(out, *i as u64);
        }
        Action::Continuous(v) => {
            out.push(1);
            put_f32s(out, v);
        }
    }
}

fn put_lane_spec(out: &mut Vec<u8>, spec: &LaneSpec) {
    put_str(out, &spec.env_id);
    put_u32(out, spec.obs_dim as u32);
    put_u64(out, spec.offset as u64);
    put_space(out, &spec.action_space);
}

/// Encode a message into a complete frame (length prefix included).
pub fn encode(msg: MsgRef<'_>) -> Vec<u8> {
    let mut payload = Vec::with_capacity(64);
    payload.push(PROTO_VERSION);
    match msg {
        MsgRef::Hello {
            spec,
            base_seed,
            first_lane,
        } => {
            payload.push(TAG_HELLO);
            put_str(&mut payload, spec);
            put_u64(&mut payload, base_seed);
            put_u64(&mut payload, first_lane);
        }
        MsgRef::Spec {
            obs_dim,
            lane_specs,
        } => {
            payload.push(TAG_SPEC);
            put_u64(&mut payload, obs_dim);
            put_u32(&mut payload, lane_specs.len() as u32);
            for spec in lane_specs {
                put_lane_spec(&mut payload, spec);
            }
        }
        MsgRef::Reset => payload.push(TAG_RESET),
        MsgRef::Obs { obs } => {
            payload.push(TAG_OBS);
            put_f32s(&mut payload, obs);
        }
        MsgRef::Step { actions } => {
            payload.push(TAG_STEP);
            put_u32(&mut payload, actions.len() as u32);
            for action in actions {
                put_action(&mut payload, action);
            }
        }
        MsgRef::StepResult { obs, transitions } => {
            payload.push(TAG_STEP_RESULT);
            put_f32s(&mut payload, obs);
            put_u32(&mut payload, transitions.len() as u32);
            for t in transitions {
                put_f32(&mut payload, t.reward);
                payload.push(t.done as u8);
                payload.push(t.truncated as u8);
            }
        }
        MsgRef::RandomRollout { steps_per_lane } => {
            payload.push(TAG_RANDOM_ROLLOUT);
            put_u64(&mut payload, steps_per_lane);
        }
        MsgRef::RolloutDone { steps, episodes } => {
            payload.push(TAG_ROLLOUT_DONE);
            put_u64(&mut payload, steps);
            put_u64(&mut payload, episodes);
        }
        MsgRef::Close => payload.push(TAG_CLOSE),
        MsgRef::Error { message } => {
            payload.push(TAG_ERROR);
            put_str(&mut payload, message);
        }
    }
    let sum = checksum(&payload);
    payload.extend_from_slice(&sum.to_le_bytes());

    let mut frame = Vec::with_capacity(4 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// Bounds-checked cursor over a payload; every accessor fails with a
/// [`CairlError::Shard`] instead of slicing out of range.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(err(format!(
                "truncated frame: wanted {n} more bytes, have {}",
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(err(format!("bad bool byte {other}"))),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// A `usize` carried as u64 (rejects values beyond the platform).
    fn size(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| err("size field overflows usize"))
    }

    /// Element count with a remaining-bytes sanity bound: `count *
    /// min_elem_size` may never exceed what is left, so a corrupt count
    /// cannot drive a huge allocation.
    fn count(&mut self, min_elem_size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_size) > self.remaining() {
            return Err(err(format!(
                "count {n} exceeds the bytes left in the frame ({})",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| err("string field is not UTF-8"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    fn space(&mut self) -> Result<Space> {
        match self.u8()? {
            0 => Ok(Space::Discrete {
                n: self.size()?,
            }),
            1 => {
                let low = self.f32s()?;
                let high = self.f32s()?;
                if low.len() != high.len() {
                    return Err(err("box space low/high length mismatch"));
                }
                let dims = self.count(8)?;
                let mut shape = Vec::with_capacity(dims);
                for _ in 0..dims {
                    shape.push(self.size()?);
                }
                Ok(Space::Box { low, high, shape })
            }
            other => Err(err(format!("bad space tag {other}"))),
        }
    }

    fn action(&mut self) -> Result<Action> {
        match self.u8()? {
            0 => Ok(Action::Discrete(self.size()?)),
            1 => Ok(Action::Continuous(self.f32s()?)),
            other => Err(err(format!("bad action tag {other}"))),
        }
    }

    fn lane_spec(&mut self) -> Result<LaneSpec> {
        Ok(LaneSpec {
            env_id: self.str()?,
            obs_dim: self.u32()? as usize,
            offset: self.size()?,
            action_space: self.space()?,
        })
    }
}

/// Decode one payload (a frame minus its length prefix): verify the
/// checksum and version, parse the tagged body, reject trailing bytes.
pub fn decode_payload(payload: &[u8]) -> Result<Msg> {
    // version + tag + checksum is the smallest possible payload.
    if payload.len() < 6 {
        return Err(err(format!("frame too short ({} bytes)", payload.len())));
    }
    let (body, sum_bytes) = payload.split_at(payload.len() - 4);
    let wire_sum = u32::from_le_bytes([sum_bytes[0], sum_bytes[1], sum_bytes[2], sum_bytes[3]]);
    let computed = checksum(body);
    if wire_sum != computed {
        return Err(err(format!(
            "checksum mismatch (wire {wire_sum:#010x}, computed {computed:#010x})"
        )));
    }
    let mut r = Reader::new(body);
    let version = r.u8()?;
    if version != PROTO_VERSION {
        return Err(err(format!(
            "protocol version mismatch (peer {version}, ours {PROTO_VERSION})"
        )));
    }
    let tag = r.u8()?;
    let msg = match tag {
        TAG_HELLO => Msg::Hello {
            spec: r.str()?,
            base_seed: r.u64()?,
            first_lane: r.u64()?,
        },
        TAG_SPEC => {
            let obs_dim = r.u64()?;
            let n = r.count(1)?;
            let mut lane_specs = Vec::with_capacity(n);
            for _ in 0..n {
                lane_specs.push(r.lane_spec()?);
            }
            Msg::Spec { obs_dim, lane_specs }
        }
        TAG_RESET => Msg::Reset,
        TAG_OBS => Msg::Obs { obs: r.f32s()? },
        TAG_STEP => {
            let n = r.count(1)?;
            let mut actions = Vec::with_capacity(n);
            for _ in 0..n {
                actions.push(r.action()?);
            }
            Msg::Step { actions }
        }
        TAG_STEP_RESULT => {
            let obs = r.f32s()?;
            let n = r.count(6)?;
            let mut transitions = Vec::with_capacity(n);
            for _ in 0..n {
                transitions.push(Transition {
                    reward: r.f32()?,
                    done: r.bool()?,
                    truncated: r.bool()?,
                });
            }
            Msg::StepResult { obs, transitions }
        }
        TAG_RANDOM_ROLLOUT => Msg::RandomRollout {
            steps_per_lane: r.u64()?,
        },
        TAG_ROLLOUT_DONE => Msg::RolloutDone {
            steps: r.u64()?,
            episodes: r.u64()?,
        },
        TAG_CLOSE => Msg::Close,
        TAG_ERROR => Msg::Error { message: r.str()? },
        other => return Err(err(format!("unknown message tag {other}"))),
    };
    if r.remaining() != 0 {
        return Err(err(format!(
            "{} trailing bytes after the message body",
            r.remaining()
        )));
    }
    Ok(msg)
}

/// Write one complete frame.
pub fn write_msg(w: &mut impl Write, msg: MsgRef<'_>) -> Result<()> {
    w.write_all(&encode(msg))?;
    w.flush()?;
    Ok(())
}

/// Read one complete frame, enforcing the length bounds before any
/// allocation.  An EOF on the length prefix surfaces as the underlying
/// [`CairlError::Io`] (a clean peer hang-up for callers to match on).
pub fn read_msg(r: &mut impl Read) -> Result<Msg> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len < 6 {
        return Err(err(format!("frame length {len} below the minimum of 6")));
    }
    if len > MAX_FRAME {
        return Err(err(format!(
            "frame length {len} exceeds the {MAX_FRAME}-byte ceiling"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    decode_payload(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: MsgRef<'_>) -> Msg {
        let frame = encode(msg);
        let mut cursor = &frame[..];
        read_msg(&mut cursor).expect("round trip")
    }

    #[test]
    fn every_message_round_trips() {
        assert_eq!(
            round_trip(MsgRef::Hello {
                spec: "CartPole-v1:4,GridRTS-v0:2",
                base_seed: 99,
                first_lane: 12,
            }),
            Msg::Hello {
                spec: "CartPole-v1:4,GridRTS-v0:2".into(),
                base_seed: 99,
                first_lane: 12,
            }
        );
        let specs = vec![
            LaneSpec {
                env_id: "CartPole-v1".into(),
                obs_dim: 4,
                offset: 0,
                action_space: Space::Discrete { n: 2 },
            },
            LaneSpec {
                env_id: "Pendulum-v1".into(),
                obs_dim: 3,
                offset: 4,
                action_space: Space::box1(vec![-2.0], vec![2.0]),
            },
        ];
        assert_eq!(
            round_trip(MsgRef::Spec {
                obs_dim: 4,
                lane_specs: &specs,
            }),
            Msg::Spec {
                obs_dim: 4,
                lane_specs: specs.clone(),
            }
        );
        assert_eq!(round_trip(MsgRef::Reset), Msg::Reset);
        let obs = vec![0.5f32, -1.25, 3.0];
        assert_eq!(
            round_trip(MsgRef::Obs { obs: &obs }),
            Msg::Obs { obs: obs.clone() }
        );
        let actions = vec![Action::Discrete(1), Action::Continuous(vec![0.5, -0.5])];
        assert_eq!(
            round_trip(MsgRef::Step { actions: &actions }),
            Msg::Step {
                actions: actions.clone(),
            }
        );
        let transitions = vec![
            Transition::live(1.0),
            Transition {
                reward: -0.5,
                done: false,
                truncated: true,
            },
        ];
        assert_eq!(
            round_trip(MsgRef::StepResult {
                obs: &obs,
                transitions: &transitions,
            }),
            Msg::StepResult {
                obs: obs.clone(),
                transitions: transitions.clone(),
            }
        );
        assert_eq!(
            round_trip(MsgRef::RandomRollout { steps_per_lane: 7 }),
            Msg::RandomRollout { steps_per_lane: 7 }
        );
        assert_eq!(
            round_trip(MsgRef::RolloutDone {
                steps: 700,
                episodes: 31,
            }),
            Msg::RolloutDone {
                steps: 700,
                episodes: 31,
            }
        );
        assert_eq!(round_trip(MsgRef::Close), Msg::Close);
        assert_eq!(
            round_trip(MsgRef::Error { message: "boom" }),
            Msg::Error {
                message: "boom".into(),
            }
        );
    }

    #[test]
    fn corrupt_frames_error_without_panicking() {
        let frame = encode(MsgRef::Hello {
            spec: "CartPole-v1",
            base_seed: 3,
            first_lane: 0,
        });
        // Flip every single byte in turn: each corruption must be an
        // error (length, checksum, version or body), never a panic or a
        // silently different message.
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x41;
            let mut cursor = &bad[..];
            match read_msg(&mut cursor) {
                Ok(msg) => {
                    // A flipped length byte may reframe into a valid
                    // message only if the checksum still holds — which a
                    // 1-bit flip cannot arrange.
                    panic!("byte {i} corruption decoded as {msg:?}");
                }
                Err(e) => assert!(
                    matches!(e, CairlError::Shard(_) | CairlError::Io(_)),
                    "byte {i}: unexpected error kind {e}"
                ),
            }
        }
    }

    #[test]
    fn truncated_frames_error_at_every_length() {
        let frame = encode(MsgRef::Step {
            actions: &[Action::Discrete(0), Action::Continuous(vec![1.0])],
        });
        for keep in 0..frame.len() {
            let mut cursor = &frame[..keep];
            assert!(
                read_msg(&mut cursor).is_err(),
                "truncation to {keep} bytes must not decode"
            );
        }
    }

    #[test]
    fn hostile_lengths_and_counts_are_bounded() {
        // A frame claiming a 4 GiB payload dies on the length check.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        huge.extend_from_slice(&[0u8; 16]);
        let mut cursor = &huge[..];
        assert!(read_msg(&mut cursor).is_err());

        // A valid envelope around a hostile element count dies on the
        // count-vs-remaining bound, not in the allocator.
        let mut payload = vec![PROTO_VERSION, TAG_OBS];
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count
        let sum = checksum(&payload);
        payload.extend_from_slice(&sum.to_le_bytes());
        assert!(decode_payload(&payload).is_err());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut frame = encode(MsgRef::Close);
        // Rewrite the version byte and fix the checksum up so only the
        // version check can fire.
        frame[4] = PROTO_VERSION + 1;
        let body_end = frame.len() - 4;
        let sum = checksum(&frame[4..body_end]);
        frame[body_end..].copy_from_slice(&sum.to_le_bytes());
        let mut cursor = &frame[..];
        let e = read_msg(&mut cursor).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
    }
}
