//! The shard wire protocol: compact length-prefixed binary frames.
//!
//! The normative specification lives in `docs/shard-protocol.md`; this
//! module is the implementation.  Every message travels as one frame:
//!
//! ```text
//! [len: u32 LE] [payload: len bytes]
//! payload = [version: u8] [tag: u8] [seq: u32 LE] [body ...] [checksum: u32 LE]
//! ```
//!
//! `len` counts the payload (version through checksum).  The checksum is
//! FNV-1a/32 over `version..body` (sequence number included), so a
//! flipped bit anywhere in a frame is rejected before the body is even
//! parsed.  Frames larger than [`MAX_FRAME`] are refused outright — a
//! corrupt length prefix can never drive a gigabyte allocation.
//!
//! **Sequence numbers** make the fabric pipelinable: each side stamps
//! its requests with a monotonically increasing `seq` starting at 1
//! (`Hello` is seq 1), replies echo the seq of the request they answer,
//! and [`SeqTracker`] enforces the strict successor rule on receipt —
//! a duplicated, stale or reordered frame is detected immediately
//! instead of silently desynchronising lane state.  Seq [`SEQ_NONE`]
//! (zero) is reserved for server `Error` frames emitted before any
//! request seq is known (an undecodable first frame).
//!
//! Decoding is **total**: every read is bounds-checked and every invalid
//! input (truncated body, bad tag, bad bool, non-UTF-8 string, trailing
//! garbage, checksum mismatch) returns [`CairlError::Shard`] — the
//! decoder never panics, which `rust/tests/shard_pool.rs` fuzzes.
//!
//! The message set mirrors the [`BatchedExecutor`]
//! (crate::coordinator::pool::BatchedExecutor) surface: a `Hello`
//! handshake answered by `Spec` (reusing [`LaneSpec`] so the client sees
//! exactly the metadata a local pool would report) or `Busy` (admission
//! control), `Reset`/`Obs`, `Step`/`StepResult` with f32 observation
//! payloads, a whole-workload `RandomRollout`/`RolloutDone` pair (the
//! free-running throughput mode crosses the wire **once**),
//! `Status`/`StatusReport` for daemon introspection, `Ping`/`Pong`
//! liveness probes (valid before any `Hello`, no token required),
//! `Close` and `Error`.
//!
//! Two enums, one format: [`MsgRef`] borrows its payloads for
//! allocation-light encoding on the hot path, [`Msg`] owns them for
//! decoding; `decode(encode(m))` round-trips every message.

use std::io::{Read, Write};

use crate::coordinator::pool::LaneSpec;
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::core::spaces::{Action, Space};
use crate::telemetry::trace::TraceCtx;

/// Protocol revision; bumped on any wire-format change.  A frame whose
/// version byte differs is rejected at decode — there is no negotiation
/// (both halves ship in one binary; see `docs/shard-protocol.md` for
/// the compatibility story).  v4: `Obs`/`StepResult` observation blocks
/// are tail-elided — each lane ships its true (unpadded) width and the
/// client re-pads, so padding zeros never cross the wire.  v5:
/// `Ping`/`Pong` liveness frames, per-frame read/write deadline
/// semantics, and the drain handshake (`Hello` during drain answered
/// with `Busy`).  v6: `Hello` and every per-batch request
/// (`Reset`/`Step`/`RandomRollout`) carry a fixed 16-byte trace
/// context (trace id + parent span id, zeros when untraced) directly
/// after the sequence number, and their replies
/// (`Obs`/`StepResult`/`RolloutDone`) carry a 16-byte [`ServerTiming`]
/// block so server-side decode/step spans stitch under the client's
/// batch span.
pub const PROTO_VERSION: u8 = 6;

/// Hard ceiling on payload length (64 MiB) — refuse corrupt length
/// prefixes before allocating.
pub const MAX_FRAME: usize = 1 << 26;

/// The reserved "no sequence" number: never assigned to a request, used
/// by server `Error` frames sent before a request seq is known.
pub const SEQ_NONE: u32 = 0;

const TAG_HELLO: u8 = 1;
const TAG_SPEC: u8 = 2;
const TAG_RESET: u8 = 3;
const TAG_OBS: u8 = 4;
const TAG_STEP: u8 = 5;
const TAG_STEP_RESULT: u8 = 6;
const TAG_RANDOM_ROLLOUT: u8 = 7;
const TAG_ROLLOUT_DONE: u8 = 8;
const TAG_CLOSE: u8 = 9;
const TAG_ERROR: u8 = 10;
const TAG_STATUS: u8 = 11;
const TAG_STATUS_REPORT: u8 = 12;
const TAG_BUSY: u8 = 13;
const TAG_PING: u8 = 14;
const TAG_PONG: u8 = 15;

/// Server-measured durations carried on v6 reply frames
/// (`Obs`/`StepResult`/`RolloutDone`): how long the daemon spent
/// decoding the request payload and stepping its executor.  Durations,
/// not timestamps — the two processes share no clock; the client
/// centres the stitched spans inside its own wire window
/// (`shard/client.rs`).  All-zero when the request carried no trace
/// context.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerTiming {
    /// Nanoseconds spent decoding the request payload (checksum +
    /// parse, excluding blocking reads).
    pub decode_ns: u64,
    /// Nanoseconds spent in the executor (`reset_into` / `step_into` /
    /// the rollout loop).
    pub step_ns: u64,
}

/// The successor of `seq` in the 1-based sequence space (wraps around
/// [`SEQ_NONE`], which is reserved).
pub fn next_seq(seq: u32) -> u32 {
    match seq.wrapping_add(1) {
        SEQ_NONE => 1,
        v => v,
    }
}

/// Enforces the strict-successor sequencing rule on one direction of a
/// connection: requests on the server side, reply echoes on the client
/// side.  [`SeqTracker::accept`] distinguishes stale/duplicated frames
/// from gaps (a reordered or dropped frame) so the error names the
/// actual fault.
#[derive(Clone, Debug, Default)]
pub struct SeqTracker {
    last: u32,
}

impl SeqTracker {
    /// A fresh tracker: the first acceptable sequence number is 1.
    pub fn new() -> SeqTracker {
        SeqTracker { last: SEQ_NONE }
    }

    /// The next sequence number this tracker will accept.
    pub fn expected(&self) -> u32 {
        next_seq(self.last)
    }

    /// Accept `seq` if it is the expected successor, otherwise report
    /// what went wrong without mutating the tracker.
    pub fn accept(&mut self, seq: u32) -> Result<()> {
        let expected = next_seq(self.last);
        if seq == expected {
            self.last = seq;
            return Ok(());
        }
        if seq == SEQ_NONE {
            return Err(err(format!(
                "frame carries reserved sequence number 0 (expected {expected})"
            )));
        }
        if seq.wrapping_sub(expected) > u32::MAX / 2 {
            // seq < expected modulo wrap: the peer re-sent old traffic.
            Err(err(format!(
                "stale or duplicated frame: sequence {seq}, expected {expected}"
            )))
        } else {
            Err(err(format!(
                "sequence gap: got {seq}, expected {expected} (reordered or dropped frame)"
            )))
        }
    }
}

/// One decoded frame: the echoed/assigned sequence number plus the
/// message it carries.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    /// Sequence number stamped by the sender ([`SEQ_NONE`] only on
    /// server `Error` frames emitted before a request seq is known).
    pub seq: u32,
    /// The decoded message body.
    pub msg: Msg,
}

/// An outbound message, borrowing its payloads (no clone to send a
/// `&[Action]` or an observation buffer).
#[derive(Clone, Copy, Debug)]
pub enum MsgRef<'a> {
    /// Client handshake: the env spec the shard should host (empty =
    /// the daemon's configured default), the pool-wide base seed and
    /// this shard's first global lane.  The shard seeds local lane `j`
    /// with `base_seed + first_lane + j`, so a sharded pool's lanes hold
    /// exactly the RNG streams of the equivalent local pool.
    Hello {
        /// Env spec to host (`""` = the daemon's configured default).
        spec: &'a str,
        /// Pool-wide base seed.
        base_seed: u64,
        /// First global lane index hosted by this shard.
        first_lane: u64,
        /// Requested pipeline depth (outstanding batches); informational
        /// for the daemon's status report.
        pipeline: u32,
        /// Auth token (`""` when the daemon runs without `--token`).
        token: &'a str,
        /// Pool-level wrapper chain applied to every hosted lane,
        /// rendered in the `--wrap` grammar (`""` = the daemon's
        /// configured default, which itself defaults to no wrappers).
        wrap: &'a str,
        /// Connection-level trace context (v6): the client pool's trace
        /// id, parent span 0.  [`TraceCtx::NONE`] when untraced.
        ctx: TraceCtx,
    },
    /// Server handshake reply: the hosted executor's padded width and
    /// per-lane metadata (shard-local offsets).
    Spec {
        /// Shard-local padded observation width.
        obs_dim: u64,
        /// Per-lane metadata, shard-local lane order.
        lane_specs: &'a [LaneSpec],
    },
    /// Reset every lane; answered by [`MsgRef::Obs`].
    Reset {
        /// Trace context of the client-side reset span (v6);
        /// [`TraceCtx::NONE`] when untraced.  A failover replay re-sends
        /// the *original* context (`docs/shard-protocol.md` §7).
        ctx: TraceCtx,
    },
    /// A `[lanes * obs_dim]` observation block (shard-local padding).
    Obs {
        /// The observation block.
        obs: &'a [f32],
        /// Server-measured decode/step durations (v6).
        timing: ServerTiming,
    },
    /// One lockstep batch of actions, lane order; answered by
    /// [`MsgRef::StepResult`].
    Step {
        /// One action per hosted lane, lane order.
        actions: &'a [Action],
        /// Trace context of the client-side batch span (v6);
        /// [`TraceCtx::NONE`] when untraced.  A failover replay re-sends
        /// the *original* context (`docs/shard-protocol.md` §7).
        ctx: TraceCtx,
    },
    /// Batch step reply: the observation block plus per-lane transitions.
    StepResult {
        /// The post-step observation block.
        obs: &'a [f32],
        /// One transition per hosted lane, lane order.
        transitions: &'a [Transition],
        /// Server-measured decode/step durations (v6).
        timing: ServerTiming,
    },
    /// Run a whole free-running random rollout shard-side; answered by
    /// [`MsgRef::RolloutDone`].
    RandomRollout {
        /// Steps each lane advances before the rollout stops.
        steps_per_lane: u64,
        /// Trace context (v6); [`TraceCtx::NONE`] when untraced.
        ctx: TraceCtx,
    },
    /// Aggregate counts of a completed shard-side rollout.
    RolloutDone {
        /// Total env steps taken across the shard's lanes.
        steps: u64,
        /// Episodes completed across the shard's lanes.
        episodes: u64,
        /// Server-measured decode/rollout durations (v6).
        timing: ServerTiming,
    },
    /// Ask the daemon for its status report; answered by
    /// [`MsgRef::StatusReport`].  Valid before any `Hello`.
    Status {
        /// Auth token (checked exactly like `Hello`'s).
        token: &'a str,
    },
    /// Daemon introspection reply: a JSON document (uptime, lane budget,
    /// per-client table) rendered server-side.
    StatusReport {
        /// The JSON status document.
        report: &'a str,
    },
    /// Admission-control reply to `Hello`: the daemon's lane budget is
    /// exhausted.  The connection stays open — the client may retry the
    /// handshake after `retry_ms`.
    Busy {
        /// Lanes currently reserved by connected clients.
        active_lanes: u64,
        /// The daemon's `--max-lanes` budget.
        max_lanes: u64,
        /// Suggested client back-off before re-sending `Hello`.
        retry_ms: u64,
    },
    /// Client-initiated liveness probe; answered by [`MsgRef::Pong`]
    /// echoing the nonce.  Valid at any point — including before
    /// `Hello` and without a token — because it reveals nothing beyond
    /// liveness.
    Ping {
        /// Opaque value echoed back in the matching `Pong`.
        nonce: u64,
    },
    /// Liveness reply: the nonce of the `Ping` it answers.
    Pong {
        /// Echo of the probe's nonce.
        nonce: u64,
    },
    /// Orderly hang-up.
    Close,
    /// Server-side failure (bad spec, wrong action count, bad sequence
    /// number, bad token, executor panic); the connection closes after
    /// this frame.
    Error {
        /// Human-readable description of the failure.
        message: &'a str,
    },
}

/// A decoded (owned) message; the receive-side mirror of [`MsgRef`].
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// See [`MsgRef::Hello`].
    Hello {
        /// Env spec to host (`""` = the daemon's configured default).
        spec: String,
        /// Pool-wide base seed.
        base_seed: u64,
        /// First global lane index hosted by this shard.
        first_lane: u64,
        /// Requested pipeline depth (outstanding batches).
        pipeline: u32,
        /// Auth token (`""` when unauthenticated).
        token: String,
        /// Pool-level wrapper chain (`--wrap` grammar; `""` = the
        /// daemon's configured default).
        wrap: String,
        /// Connection-level trace context (v6).
        ctx: TraceCtx,
    },
    /// See [`MsgRef::Spec`].
    Spec {
        /// Shard-local padded observation width.
        obs_dim: u64,
        /// Per-lane metadata, shard-local lane order.
        lane_specs: Vec<LaneSpec>,
    },
    /// See [`MsgRef::Reset`].
    Reset {
        /// Trace context of the client-side reset span (v6).
        ctx: TraceCtx,
    },
    /// See [`MsgRef::Obs`].
    Obs {
        /// The observation block.
        obs: Vec<f32>,
        /// Server-measured decode/step durations (v6).
        timing: ServerTiming,
    },
    /// See [`MsgRef::Step`].
    Step {
        /// One action per hosted lane, lane order.
        actions: Vec<Action>,
        /// Trace context of the client-side batch span (v6).
        ctx: TraceCtx,
    },
    /// See [`MsgRef::StepResult`].
    StepResult {
        /// The post-step observation block.
        obs: Vec<f32>,
        /// One transition per hosted lane, lane order.
        transitions: Vec<Transition>,
        /// Server-measured decode/step durations (v6).
        timing: ServerTiming,
    },
    /// See [`MsgRef::RandomRollout`].
    RandomRollout {
        /// Steps each lane advances before the rollout stops.
        steps_per_lane: u64,
        /// Trace context (v6).
        ctx: TraceCtx,
    },
    /// See [`MsgRef::RolloutDone`].
    RolloutDone {
        /// Total env steps taken across the shard's lanes.
        steps: u64,
        /// Episodes completed across the shard's lanes.
        episodes: u64,
        /// Server-measured decode/rollout durations (v6).
        timing: ServerTiming,
    },
    /// See [`MsgRef::Status`].
    Status {
        /// Auth token (checked exactly like `Hello`'s).
        token: String,
    },
    /// See [`MsgRef::StatusReport`].
    StatusReport {
        /// The JSON status document.
        report: String,
    },
    /// See [`MsgRef::Busy`].
    Busy {
        /// Lanes currently reserved by connected clients.
        active_lanes: u64,
        /// The daemon's `--max-lanes` budget.
        max_lanes: u64,
        /// Suggested client back-off before re-sending `Hello`.
        retry_ms: u64,
    },
    /// See [`MsgRef::Ping`].
    Ping {
        /// Opaque value echoed back in the matching `Pong`.
        nonce: u64,
    },
    /// See [`MsgRef::Pong`].
    Pong {
        /// Echo of the probe's nonce.
        nonce: u64,
    },
    /// See [`MsgRef::Close`].
    Close,
    /// See [`MsgRef::Error`].
    Error {
        /// Human-readable description of the failure.
        message: String,
    },
}

fn err(msg: impl Into<String>) -> CairlError {
    CairlError::Shard(msg.into())
}

/// FNV-1a/32 over a byte slice — the frame checksum.
fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        put_f32(out, x);
    }
}

fn put_space(out: &mut Vec<u8>, space: &Space) {
    match space {
        Space::Discrete { n } => {
            out.push(0);
            put_u64(out, *n as u64);
        }
        Space::Box { low, high, shape } => {
            out.push(1);
            put_f32s(out, low);
            put_f32s(out, high);
            put_u32(out, shape.len() as u32);
            for &d in shape {
                put_u64(out, d as u64);
            }
        }
    }
}

fn put_action(out: &mut Vec<u8>, action: &Action) {
    match action {
        Action::Discrete(i) => {
            out.push(0);
            put_u64(out, *i as u64);
        }
        Action::Continuous(v) => {
            out.push(1);
            put_f32s(out, v);
        }
    }
}

fn put_lane_spec(out: &mut Vec<u8>, spec: &LaneSpec) {
    put_str(out, &spec.env_id);
    put_u32(out, spec.obs_dim as u32);
    put_u64(out, spec.offset as u64);
    put_space(out, &spec.action_space);
}

/// The fixed 16-byte v6 trace context: trace id then parent span id,
/// both u64 LE, zeros when untraced.
fn put_ctx(out: &mut Vec<u8>, ctx: TraceCtx) {
    put_u64(out, ctx.trace_id);
    put_u64(out, ctx.span_id);
}

/// The fixed 16-byte v6 server-timing block: decode then step
/// nanoseconds, both u64 LE.
fn put_timing(out: &mut Vec<u8>, t: ServerTiming) {
    put_u64(out, t.decode_ns);
    put_u64(out, t.step_ns);
}

/// Encode a message into a complete frame (length prefix included),
/// stamped with `seq`.
pub fn encode(seq: u32, msg: MsgRef<'_>) -> Vec<u8> {
    let mut payload = Vec::with_capacity(64);
    payload.push(PROTO_VERSION);
    match msg {
        MsgRef::Hello {
            spec,
            base_seed,
            first_lane,
            pipeline,
            token,
            wrap,
            ctx,
        } => {
            payload.push(TAG_HELLO);
            put_u32(&mut payload, seq);
            put_ctx(&mut payload, ctx);
            put_str(&mut payload, spec);
            put_u64(&mut payload, base_seed);
            put_u64(&mut payload, first_lane);
            put_u32(&mut payload, pipeline);
            put_str(&mut payload, token);
            put_str(&mut payload, wrap);
        }
        MsgRef::Spec {
            obs_dim,
            lane_specs,
        } => {
            payload.push(TAG_SPEC);
            put_u32(&mut payload, seq);
            put_u64(&mut payload, obs_dim);
            put_u32(&mut payload, lane_specs.len() as u32);
            for spec in lane_specs {
                put_lane_spec(&mut payload, spec);
            }
        }
        MsgRef::Reset { ctx } => {
            payload.push(TAG_RESET);
            put_u32(&mut payload, seq);
            put_ctx(&mut payload, ctx);
        }
        MsgRef::Obs { obs, timing } => {
            payload.push(TAG_OBS);
            put_u32(&mut payload, seq);
            put_timing(&mut payload, timing);
            put_f32s(&mut payload, obs);
        }
        MsgRef::Step { actions, ctx } => {
            payload.push(TAG_STEP);
            put_u32(&mut payload, seq);
            put_ctx(&mut payload, ctx);
            put_u32(&mut payload, actions.len() as u32);
            for action in actions {
                put_action(&mut payload, action);
            }
        }
        MsgRef::StepResult {
            obs,
            transitions,
            timing,
        } => {
            payload.push(TAG_STEP_RESULT);
            put_u32(&mut payload, seq);
            put_timing(&mut payload, timing);
            put_f32s(&mut payload, obs);
            put_u32(&mut payload, transitions.len() as u32);
            for t in transitions {
                put_f32(&mut payload, t.reward);
                payload.push(t.done as u8);
                payload.push(t.truncated as u8);
            }
        }
        MsgRef::RandomRollout {
            steps_per_lane,
            ctx,
        } => {
            payload.push(TAG_RANDOM_ROLLOUT);
            put_u32(&mut payload, seq);
            put_ctx(&mut payload, ctx);
            put_u64(&mut payload, steps_per_lane);
        }
        MsgRef::RolloutDone {
            steps,
            episodes,
            timing,
        } => {
            payload.push(TAG_ROLLOUT_DONE);
            put_u32(&mut payload, seq);
            put_timing(&mut payload, timing);
            put_u64(&mut payload, steps);
            put_u64(&mut payload, episodes);
        }
        MsgRef::Status { token } => {
            payload.push(TAG_STATUS);
            put_u32(&mut payload, seq);
            put_str(&mut payload, token);
        }
        MsgRef::StatusReport { report } => {
            payload.push(TAG_STATUS_REPORT);
            put_u32(&mut payload, seq);
            put_str(&mut payload, report);
        }
        MsgRef::Busy {
            active_lanes,
            max_lanes,
            retry_ms,
        } => {
            payload.push(TAG_BUSY);
            put_u32(&mut payload, seq);
            put_u64(&mut payload, active_lanes);
            put_u64(&mut payload, max_lanes);
            put_u64(&mut payload, retry_ms);
        }
        MsgRef::Ping { nonce } => {
            payload.push(TAG_PING);
            put_u32(&mut payload, seq);
            put_u64(&mut payload, nonce);
        }
        MsgRef::Pong { nonce } => {
            payload.push(TAG_PONG);
            put_u32(&mut payload, seq);
            put_u64(&mut payload, nonce);
        }
        MsgRef::Close => {
            payload.push(TAG_CLOSE);
            put_u32(&mut payload, seq);
        }
        MsgRef::Error { message } => {
            payload.push(TAG_ERROR);
            put_u32(&mut payload, seq);
            put_str(&mut payload, message);
        }
    }
    let sum = checksum(&payload);
    payload.extend_from_slice(&sum.to_le_bytes());

    let mut frame = Vec::with_capacity(4 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// Bounds-checked cursor over a payload; every accessor fails with a
/// [`CairlError::Shard`] instead of slicing out of range.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(err(format!(
                "truncated frame: wanted {n} more bytes, have {}",
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(err(format!("bad bool byte {other}"))),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// A `usize` carried as u64 (rejects values beyond the platform).
    fn size(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| err("size field overflows usize"))
    }

    /// Element count with a remaining-bytes sanity bound: `count *
    /// min_elem_size` may never exceed what is left, so a corrupt count
    /// cannot drive a huge allocation.
    fn count(&mut self, min_elem_size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_size) > self.remaining() {
            return Err(err(format!(
                "count {n} exceeds the bytes left in the frame ({})",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| err("string field is not UTF-8"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    fn space(&mut self) -> Result<Space> {
        match self.u8()? {
            0 => Ok(Space::Discrete {
                n: self.size()?,
            }),
            1 => {
                let low = self.f32s()?;
                let high = self.f32s()?;
                if low.len() != high.len() {
                    return Err(err("box space low/high length mismatch"));
                }
                let dims = self.count(8)?;
                let mut shape = Vec::with_capacity(dims);
                for _ in 0..dims {
                    shape.push(self.size()?);
                }
                Ok(Space::Box { low, high, shape })
            }
            other => Err(err(format!("bad space tag {other}"))),
        }
    }

    fn action(&mut self) -> Result<Action> {
        match self.u8()? {
            0 => Ok(Action::Discrete(self.size()?)),
            1 => Ok(Action::Continuous(self.f32s()?)),
            other => Err(err(format!("bad action tag {other}"))),
        }
    }

    fn lane_spec(&mut self) -> Result<LaneSpec> {
        Ok(LaneSpec {
            env_id: self.str()?,
            obs_dim: self.u32()? as usize,
            offset: self.size()?,
            action_space: self.space()?,
        })
    }

    /// The fixed 16-byte v6 trace context.  A short read here reports
    /// "truncated frame" like any other field — a partial context can
    /// never decode.
    fn ctx(&mut self) -> Result<TraceCtx> {
        Ok(TraceCtx {
            trace_id: self.u64()?,
            span_id: self.u64()?,
        })
    }

    /// The fixed 16-byte v6 server-timing block.
    fn timing(&mut self) -> Result<ServerTiming> {
        Ok(ServerTiming {
            decode_ns: self.u64()?,
            step_ns: self.u64()?,
        })
    }
}

/// Decode one payload (a frame minus its length prefix): verify the
/// checksum and version, parse the sequence number and tagged body,
/// reject trailing bytes.
pub fn decode_payload(payload: &[u8]) -> Result<Frame> {
    // version + tag + seq + checksum is the smallest possible payload.
    if payload.len() < 10 {
        return Err(err(format!("frame too short ({} bytes)", payload.len())));
    }
    let (body, sum_bytes) = payload.split_at(payload.len() - 4);
    let wire_sum = u32::from_le_bytes([sum_bytes[0], sum_bytes[1], sum_bytes[2], sum_bytes[3]]);
    let computed = checksum(body);
    if wire_sum != computed {
        return Err(err(format!(
            "checksum mismatch (wire {wire_sum:#010x}, computed {computed:#010x})"
        )));
    }
    let mut r = Reader::new(body);
    let version = r.u8()?;
    if version != PROTO_VERSION {
        return Err(err(format!(
            "protocol version mismatch (peer {version}, ours {PROTO_VERSION}); \
             both halves must run the same cairl build"
        )));
    }
    let tag = r.u8()?;
    let seq = r.u32()?;
    let msg = match tag {
        TAG_HELLO => {
            let ctx = r.ctx()?;
            Msg::Hello {
                spec: r.str()?,
                base_seed: r.u64()?,
                first_lane: r.u64()?,
                pipeline: r.u32()?,
                token: r.str()?,
                wrap: r.str()?,
                ctx,
            }
        }
        TAG_SPEC => {
            let obs_dim = r.u64()?;
            let n = r.count(1)?;
            let mut lane_specs = Vec::with_capacity(n);
            for _ in 0..n {
                lane_specs.push(r.lane_spec()?);
            }
            Msg::Spec { obs_dim, lane_specs }
        }
        TAG_RESET => Msg::Reset { ctx: r.ctx()? },
        TAG_OBS => {
            let timing = r.timing()?;
            Msg::Obs {
                obs: r.f32s()?,
                timing,
            }
        }
        TAG_STEP => {
            let ctx = r.ctx()?;
            let n = r.count(1)?;
            let mut actions = Vec::with_capacity(n);
            for _ in 0..n {
                actions.push(r.action()?);
            }
            Msg::Step { actions, ctx }
        }
        TAG_STEP_RESULT => {
            let timing = r.timing()?;
            let obs = r.f32s()?;
            let n = r.count(6)?;
            let mut transitions = Vec::with_capacity(n);
            for _ in 0..n {
                transitions.push(Transition {
                    reward: r.f32()?,
                    done: r.bool()?,
                    truncated: r.bool()?,
                });
            }
            Msg::StepResult {
                obs,
                transitions,
                timing,
            }
        }
        TAG_RANDOM_ROLLOUT => {
            let ctx = r.ctx()?;
            Msg::RandomRollout {
                steps_per_lane: r.u64()?,
                ctx,
            }
        }
        TAG_ROLLOUT_DONE => {
            let timing = r.timing()?;
            Msg::RolloutDone {
                steps: r.u64()?,
                episodes: r.u64()?,
                timing,
            }
        }
        TAG_STATUS => Msg::Status { token: r.str()? },
        TAG_STATUS_REPORT => Msg::StatusReport { report: r.str()? },
        TAG_BUSY => Msg::Busy {
            active_lanes: r.u64()?,
            max_lanes: r.u64()?,
            retry_ms: r.u64()?,
        },
        TAG_PING => Msg::Ping { nonce: r.u64()? },
        TAG_PONG => Msg::Pong { nonce: r.u64()? },
        TAG_CLOSE => Msg::Close,
        TAG_ERROR => Msg::Error { message: r.str()? },
        other => return Err(err(format!("unknown message tag {other}"))),
    };
    if r.remaining() != 0 {
        return Err(err(format!(
            "{} trailing bytes after the message body",
            r.remaining()
        )));
    }
    Ok(Frame { seq, msg })
}

/// Write one complete frame stamped with `seq`.
pub fn write_msg(w: &mut impl Write, seq: u32, msg: MsgRef<'_>) -> Result<()> {
    w.write_all(&encode(seq, msg))?;
    w.flush()?;
    Ok(())
}

/// Read one complete frame, enforcing the length bounds before any
/// allocation.  An EOF on the length prefix surfaces as the underlying
/// [`CairlError::Io`] (a clean peer hang-up for callers to match on).
pub fn read_msg(r: &mut impl Read) -> Result<Frame> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len < 10 {
        return Err(err(format!("frame length {len} below the minimum of 10")));
    }
    if len > MAX_FRAME {
        return Err(err(format!(
            "frame length {len} exceeds the {MAX_FRAME}-byte ceiling"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    decode_payload(&payload)
}

/// [`read_msg`], also reporting the nanoseconds spent in
/// [`decode_payload`] — the pure CPU cost of checksum + parse,
/// excluding any blocking socket reads.  The serve daemon feeds this
/// into the v6 [`ServerTiming`] reply block (`decode_ns`).
pub fn read_msg_timed(r: &mut impl Read) -> Result<(Frame, u64)> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len < 10 {
        return Err(err(format!("frame length {len} below the minimum of 10")));
    }
    if len > MAX_FRAME {
        return Err(err(format!(
            "frame length {len} exceeds the {MAX_FRAME}-byte ceiling"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let t0 = std::time::Instant::now();
    let frame = decode_payload(&payload)?;
    Ok((frame, t0.elapsed().as_nanos() as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(seq: u32, msg: MsgRef<'_>) -> Frame {
        let frame = encode(seq, msg);
        let mut cursor = &frame[..];
        read_msg(&mut cursor).expect("round trip")
    }

    fn framed(seq: u32, msg: Msg) -> Frame {
        Frame { seq, msg }
    }

    fn ctx() -> TraceCtx {
        TraceCtx {
            trace_id: 0x1122_3344_5566_7788,
            span_id: 42,
        }
    }

    fn timing() -> ServerTiming {
        ServerTiming {
            decode_ns: 1_500,
            step_ns: 88_000,
        }
    }

    #[test]
    fn every_message_round_trips() {
        assert_eq!(
            round_trip(
                1,
                MsgRef::Hello {
                    spec: "CartPole-v1:4,GridRTS-v0:2",
                    base_seed: 99,
                    first_lane: 12,
                    pipeline: 4,
                    token: "hunter2",
                    wrap: "TimeLimit(200),NormalizeObs",
                    ctx: ctx(),
                }
            ),
            framed(
                1,
                Msg::Hello {
                    spec: "CartPole-v1:4,GridRTS-v0:2".into(),
                    base_seed: 99,
                    first_lane: 12,
                    pipeline: 4,
                    token: "hunter2".into(),
                    wrap: "TimeLimit(200),NormalizeObs".into(),
                    ctx: ctx(),
                }
            )
        );
        let specs = vec![
            LaneSpec {
                env_id: "CartPole-v1".into(),
                obs_dim: 4,
                offset: 0,
                action_space: Space::Discrete { n: 2 },
            },
            LaneSpec {
                env_id: "Pendulum-v1".into(),
                obs_dim: 3,
                offset: 4,
                action_space: Space::box1(vec![-2.0], vec![2.0]),
            },
        ];
        assert_eq!(
            round_trip(
                1,
                MsgRef::Spec {
                    obs_dim: 4,
                    lane_specs: &specs,
                }
            ),
            framed(
                1,
                Msg::Spec {
                    obs_dim: 4,
                    lane_specs: specs.clone(),
                }
            )
        );
        assert_eq!(
            round_trip(7, MsgRef::Reset { ctx: ctx() }),
            framed(7, Msg::Reset { ctx: ctx() })
        );
        // An untraced request carries the all-zero context.
        assert_eq!(
            round_trip(7, MsgRef::Reset { ctx: TraceCtx::NONE }),
            framed(7, Msg::Reset { ctx: TraceCtx::NONE })
        );
        let obs = vec![0.5f32, -1.25, 3.0];
        assert_eq!(
            round_trip(
                8,
                MsgRef::Obs {
                    obs: &obs,
                    timing: timing(),
                }
            ),
            framed(
                8,
                Msg::Obs {
                    obs: obs.clone(),
                    timing: timing(),
                }
            )
        );
        let actions = vec![Action::Discrete(1), Action::Continuous(vec![0.5, -0.5])];
        assert_eq!(
            round_trip(
                9,
                MsgRef::Step {
                    actions: &actions,
                    ctx: ctx(),
                }
            ),
            framed(
                9,
                Msg::Step {
                    actions: actions.clone(),
                    ctx: ctx(),
                }
            )
        );
        let transitions = vec![
            Transition::live(1.0),
            Transition {
                reward: -0.5,
                done: false,
                truncated: true,
            },
        ];
        assert_eq!(
            round_trip(
                9,
                MsgRef::StepResult {
                    obs: &obs,
                    transitions: &transitions,
                    timing: timing(),
                }
            ),
            framed(
                9,
                Msg::StepResult {
                    obs: obs.clone(),
                    transitions: transitions.clone(),
                    timing: timing(),
                }
            )
        );
        assert_eq!(
            round_trip(
                10,
                MsgRef::RandomRollout {
                    steps_per_lane: 7,
                    ctx: ctx(),
                }
            ),
            framed(
                10,
                Msg::RandomRollout {
                    steps_per_lane: 7,
                    ctx: ctx(),
                }
            )
        );
        assert_eq!(
            round_trip(
                10,
                MsgRef::RolloutDone {
                    steps: 700,
                    episodes: 31,
                    timing: timing(),
                }
            ),
            framed(
                10,
                Msg::RolloutDone {
                    steps: 700,
                    episodes: 31,
                    timing: timing(),
                }
            )
        );
        assert_eq!(
            round_trip(1, MsgRef::Status { token: "" }),
            framed(1, Msg::Status { token: "".into() })
        );
        assert_eq!(
            round_trip(1, MsgRef::StatusReport { report: "{}" }),
            framed(
                1,
                Msg::StatusReport {
                    report: "{}".into()
                }
            )
        );
        assert_eq!(
            round_trip(
                1,
                MsgRef::Busy {
                    active_lanes: 96,
                    max_lanes: 128,
                    retry_ms: 50,
                }
            ),
            framed(
                1,
                Msg::Busy {
                    active_lanes: 96,
                    max_lanes: 128,
                    retry_ms: 50,
                }
            )
        );
        assert_eq!(
            round_trip(12, MsgRef::Ping { nonce: 0xdead_beef }),
            framed(12, Msg::Ping { nonce: 0xdead_beef })
        );
        assert_eq!(
            round_trip(12, MsgRef::Pong { nonce: 0xdead_beef }),
            framed(12, Msg::Pong { nonce: 0xdead_beef })
        );
        assert_eq!(round_trip(11, MsgRef::Close), framed(11, Msg::Close));
        assert_eq!(
            round_trip(SEQ_NONE, MsgRef::Error { message: "boom" }),
            framed(
                SEQ_NONE,
                Msg::Error {
                    message: "boom".into(),
                }
            )
        );
    }

    #[test]
    fn corrupt_frames_error_without_panicking() {
        let frame = encode(
            3,
            MsgRef::Hello {
                spec: "CartPole-v1",
                base_seed: 3,
                first_lane: 0,
                pipeline: 1,
                token: "",
                wrap: "",
                ctx: ctx(),
            },
        );
        // Flip every single byte in turn: each corruption must be an
        // error (length, checksum, version, seq or body), never a panic
        // or a silently different message.
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x41;
            let mut cursor = &bad[..];
            match read_msg(&mut cursor) {
                Ok(frame) => {
                    // A flipped length byte may reframe into a valid
                    // message only if the checksum still holds — which a
                    // 1-bit flip cannot arrange.
                    panic!("byte {i} corruption decoded as {frame:?}");
                }
                Err(e) => assert!(
                    matches!(e, CairlError::Shard(_) | CairlError::Io(_)),
                    "byte {i}: unexpected error kind {e}"
                ),
            }
        }
    }

    #[test]
    fn truncated_frames_error_at_every_length() {
        let frame = encode(
            5,
            MsgRef::Step {
                actions: &[Action::Discrete(0), Action::Continuous(vec![1.0])],
                ctx: ctx(),
            },
        );
        for keep in 0..frame.len() {
            let mut cursor = &frame[..keep];
            assert!(
                read_msg(&mut cursor).is_err(),
                "truncation to {keep} bytes must not decode"
            );
        }
    }

    #[test]
    fn corrupt_or_short_trace_context_is_a_protocol_error() {
        // The ctx sits at a fixed offset: len(4) + version(1) + tag(1)
        // + seq(4) = 10.  Flip each of its 16 bytes in turn — the
        // checksum must reject every one — then truncate the frame so
        // it ends mid-context and assert a clean "truncated" error.
        let frame = encode(
            2,
            MsgRef::Step {
                actions: &[Action::Discrete(1)],
                ctx: ctx(),
            },
        );
        for i in 10..26 {
            let mut bad = frame.clone();
            bad[i] ^= 0xff;
            let mut cursor = &bad[..];
            assert!(
                read_msg(&mut cursor).is_err(),
                "ctx byte {i} corruption must not decode"
            );
        }
        // Rebuild a payload that legitimately ends inside the ctx (the
        // checksum is valid, so only the truncated-field error can fire).
        let mut payload = vec![PROTO_VERSION, TAG_STEP];
        payload.extend_from_slice(&2u32.to_le_bytes()); // seq
        payload.extend_from_slice(&[0u8; 7]); // 7 of the 16 ctx bytes
        let sum = checksum(&payload);
        payload.extend_from_slice(&sum.to_le_bytes());
        let e = decode_payload(&payload).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
    }

    #[test]
    fn hostile_lengths_and_counts_are_bounded() {
        // A frame claiming a 4 GiB payload dies on the length check.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        huge.extend_from_slice(&[0u8; 16]);
        let mut cursor = &huge[..];
        assert!(read_msg(&mut cursor).is_err());

        // A valid envelope around a hostile element count dies on the
        // count-vs-remaining bound, not in the allocator.
        let mut payload = vec![PROTO_VERSION, TAG_OBS];
        payload.extend_from_slice(&1u32.to_le_bytes()); // seq
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count
        let sum = checksum(&payload);
        payload.extend_from_slice(&sum.to_le_bytes());
        assert!(decode_payload(&payload).is_err());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut frame = encode(1, MsgRef::Close);
        // Rewrite the version byte and fix the checksum up so only the
        // version check can fire.  A v1 peer fails here with a message
        // naming both revisions — the whole compatibility story.
        frame[4] = PROTO_VERSION + 1;
        let body_end = frame.len() - 4;
        let sum = checksum(&frame[4..body_end]);
        frame[body_end..].copy_from_slice(&sum.to_le_bytes());
        let mut cursor = &frame[..];
        let e = read_msg(&mut cursor).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
    }

    #[test]
    fn seq_tracker_enforces_strict_successors() {
        let mut t = SeqTracker::new();
        assert_eq!(t.expected(), 1);
        t.accept(1).unwrap();
        t.accept(2).unwrap();
        assert_eq!(t.expected(), 3);

        // Duplicate and stale frames are named as such...
        let dup = t.accept(2).unwrap_err();
        assert!(dup.to_string().contains("stale or duplicated"), "{dup}");
        let stale = t.accept(1).unwrap_err();
        assert!(stale.to_string().contains("stale or duplicated"), "{stale}");
        // ...gaps (reordered/dropped) as such...
        let gap = t.accept(5).unwrap_err();
        assert!(gap.to_string().contains("sequence gap"), "{gap}");
        // ...and the reserved zero is never a valid request seq.
        let zero = t.accept(SEQ_NONE).unwrap_err();
        assert!(zero.to_string().contains("reserved"), "{zero}");

        // A rejected frame does not advance the tracker.
        assert_eq!(t.expected(), 3);
        t.accept(3).unwrap();
    }

    #[test]
    fn seq_space_wraps_around_the_reserved_zero() {
        assert_eq!(next_seq(1), 2);
        assert_eq!(next_seq(u32::MAX), 1, "wrap skips the reserved 0");
        let mut t = SeqTracker { last: u32::MAX };
        assert_eq!(t.expected(), 1);
        t.accept(1).unwrap();
    }
}
