//! The client half: [`ShardClient`] (one connection) and
//! [`ShardedEnvPool`] (a [`BatchedExecutor`] over one or more remote
//! shards, with pipelining and transparent failover).
//!
//! A `ShardedEnvPool` is a drop-in executor: `lane_specs()`,
//! `obs_dim()`, `reset_into` and `step_into` behave identically to a
//! local pool over the same spec and seed — including **bit-identical
//! trajectories**, because each shard seeds its local lane `j` with
//! `base_seed + first_lane + j` (exactly the seed that lane holds
//! locally) and placement never reorders lanes
//! ([`ShardPlan`](crate::shard::plan::ShardPlan) cuts the lane list
//! contiguously at cost-balanced boundaries).
//!
//! **Pipelining.**  Every request frame carries a sequence number and
//! every reply echoes it, so a client may keep up to
//! [`ShardPoolOptions::pipeline`] batches in flight per shard:
//! [`ShardedEnvPool::submit_step`] sends a batch without waiting,
//! [`ShardedEnvPool::recv_oldest_step`] consumes the oldest outstanding
//! reply, and wire latency overlaps the shard's env compute.
//! [`ShardedEnvPool::run_pipelined_workload`] is the batched random
//! driver on top — it samples actions obs-independently in batch order
//! (the same RNG stream as the lockstep driver), so its episode-return
//! log is byte-identical to `run_batched_workload` on a local executor
//! at any depth.  Depth is clamped to [`MAX_PIPELINE`]: replies the
//! client has not read yet sit in OS socket buffers, so the in-flight
//! window times the reply frame size must stay comfortably inside
//! kernel buffering.
//!
//! **Failover.**  The pool keeps a replay log of every operation since
//! connect (resets, action batches, rollout commands — all
//! deterministic functions of the connection's seeding origin).  When a
//! connection dies mid-workload the pool re-dials the same address with
//! bounded exponential backoff and replays the log against the fresh
//! private executor, which reconstructs the lost lanes bit-exactly; if
//! the daemon itself is gone it re-plans the lost assignment onto a
//! surviving shard ([`FailoverConfig::replan`]).  A shard death
//! degrades; it never corrupts a trajectory.  Only when every candidate
//! is exhausted does the executor surface panic (the
//! [`BatchedExecutor`] trait has no error channel).  A deterministic
//! *remote* error (bad action count, executor panic shard-side) is
//! never retried — replaying would reproduce it.
//!
//! **Deadlines + heartbeats.**  [`ShardPoolOptions::read_timeout`] /
//! [`ShardPoolOptions::write_timeout`] arm per-frame socket deadlines
//! on every connection, so a *frozen* shard (SIGSTOP, wedged executor
//! — no connection error, just silence) surfaces as
//! [`CairlError::DeadlineExceeded`] within the bounded window and
//! routes into the same failover path as a hard disconnect.
//! [`ShardPoolOptions::heartbeat`] adds an idle `Ping`/`Pong` probe so
//! a dead shard is caught between batches too; see
//! `docs/OPERATIONS.md` for tuning.
//!
//! **Tracing.**  With tracing enabled
//! ([`trace::set_enabled`](crate::telemetry::trace::set_enabled), the
//! `cairl run --trace` path), every batch records a span tree: a
//! `batch` root, per-shard `encode` and `wire` spans, synthesized
//! server-side `decode`/`server_step` spans (placed from the durations
//! the v6 reply carries — client and shard clocks never compare, so
//! the server reports *durations* and the client centers them in the
//! observed wire window), and a `reassemble` span per shard reply.
//! Requests carry the 16-byte v6 trace context so spans the server
//! records locally stitch under the same trace id, and failover
//! replays re-send each operation's **original** context — a replayed
//! batch keeps its span ids instead of minting fresh ones.
//!
//! **Padded-obs reassembly.**  Each shard pads observations to *its
//! own* widest lane; the pool-wide padded width can be larger (a shard
//! holding only `MountainCar-v0` lanes ships 2-wide rows into a 4-wide
//! pool).  Reassembly copies each lane's true observation into its
//! global slot and re-zeroes the tail, so mixture consumers see exactly
//! the local layout.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::coordinator::experiment::SteppingResult;
use crate::coordinator::pool::{BatchedExecutor, LaneSpec, RandomRollout, RolloutCounts};
use crate::coordinator::registry::{self, MixtureEntry, MixtureSpec};
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::core::rng::Pcg32;
use crate::core::spaces::Action;
use crate::faults::{ChaosProfile, FaultPlan};
use crate::shard::net::{FramedStream, ShardAddr};
use crate::shard::plan::{calibrate_costs, ShardAssignment, ShardPlan};
use crate::shard::proto::{next_seq, Msg, MsgRef, ServerTiming, SEQ_NONE};
use crate::telemetry::trace::{self, SpanKind, SpanRecord, TraceCtx};
use crate::telemetry::{
    counter, gauge, histogram, Counter, ExecMetrics, Gauge, Histogram, LATENCY_BOUNDS_US,
};
use crate::wrappers::WrapperSpec;

/// Hard ceiling on the pipeline depth: unread replies live in OS socket
/// buffers, so the in-flight window must stay small enough that `depth
/// * reply_frame_bytes` fits kernel buffering on both ends.
pub const MAX_PIPELINE: usize = 64;

fn err(msg: impl Into<String>) -> CairlError {
    CairlError::Shard(msg.into())
}

/// Handshake knobs for a single [`ShardClient`] connection.
#[derive(Clone, Debug)]
pub struct ConnectOptions {
    /// Pipeline depth the client intends to use (reported to the daemon
    /// for its status table).
    pub pipeline: u32,
    /// Auth token (must match the daemon's `--token`; `""` = none).
    pub token: String,
    /// How many times to retry a `Hello` answered with `Busy` before
    /// giving up with [`CairlError::Unavailable`].
    pub busy_retries: u32,
    /// Pool-level wrapper chain forwarded in the `Hello` `wrap` field
    /// (`--wrap` grammar; `""` defers to the daemon's configured
    /// default).  The chain applies to every hosted lane server-side.
    pub wrap: String,
    /// Per-frame read deadline.  If the shard produces no frame within
    /// this window the call fails with
    /// [`CairlError::DeadlineExceeded`](crate::core::error::CairlError)
    /// and the connection must be abandoned (a timeout can strike
    /// mid-frame) — which is exactly what the pool's failover does.
    /// `None` (default) blocks forever, the pre-v5 behavior.
    pub read_timeout: Option<Duration>,
    /// Per-frame write deadline (a peer that stops draining its socket
    /// eventually stalls sends).  Same fatality rule as
    /// [`ConnectOptions::read_timeout`].
    pub write_timeout: Option<Duration>,
    /// Idle heartbeat interval: when set, the client sends a
    /// `Ping`/`Pong` probe before a request if the connection has been
    /// idle at least this long with nothing in flight — so a frozen
    /// shard is caught between batches, not only mid-batch.
    pub heartbeat: Option<Duration>,
    /// Trace id stamped into the `Hello` trace context (span id 0 —
    /// the handshake has no parent batch).  `0` (default) means the
    /// connection is untraced; per-batch requests still carry their own
    /// context, so the field only seeds the daemon's status attribution.
    pub trace_id: u64,
}

impl Default for ConnectOptions {
    fn default() -> ConnectOptions {
        ConnectOptions {
            pipeline: 1,
            token: String::new(),
            busy_retries: 4,
            wrap: String::new(),
            read_timeout: None,
            write_timeout: None,
            heartbeat: None,
            trace_id: 0,
        }
    }
}

/// One framed connection to a shard daemon, post-handshake.  Assigns
/// sequence numbers to outgoing requests and verifies that every reply
/// echoes the seq of the oldest in-flight request.
pub struct ShardClient {
    stream: FramedStream,
    addr: String,
    specs: Vec<LaneSpec>,
    padded: usize,
    seq_last: u32,
    pending: VecDeque<u32>,
    /// Idle-probe interval ([`ConnectOptions::heartbeat`]).
    heartbeat: Option<Duration>,
    /// Last successful send or receive on this connection.
    last_io: Instant,
    /// `cairl_heartbeats_sent_total`.
    hb_sent: Counter,
    /// `cairl_heartbeats_missed_total` (probe sent, no valid `Pong`).
    hb_missed: Counter,
}

impl ShardClient {
    /// Dial `addr`, handshake with `spec` (`""` = the daemon's default)
    /// and the seeding origin, and return the connected client with the
    /// shard's lane metadata.  Defaults: depth-1 pipeline, no token.
    pub fn connect(
        addr: &str,
        spec: &str,
        base_seed: u64,
        first_lane: usize,
    ) -> Result<ShardClient> {
        Self::connect_with(addr, spec, base_seed, first_lane, &ConnectOptions::default())
    }

    /// [`ShardClient::connect`] with explicit handshake options.  A
    /// `Busy` reply (daemon lane budget exhausted) is retried up to
    /// [`ConnectOptions::busy_retries`] times with the daemon-suggested
    /// back-off, then surfaces as [`CairlError::Unavailable`].
    pub fn connect_with(
        addr: &str,
        spec: &str,
        base_seed: u64,
        first_lane: usize,
        opts: &ConnectOptions,
    ) -> Result<ShardClient> {
        let parsed = ShardAddr::parse(addr)?;
        let mut stream = FramedStream::connect(&parsed)?;
        // Deadlines arm before the handshake so a frozen daemon (e.g. a
        // SIGSTOP'd process whose kernel still accepts connects) fails
        // the Spec read within the bounded window instead of hanging.
        stream.set_deadlines(opts.read_timeout, opts.write_timeout)?;
        let mut seq_last = SEQ_NONE;
        let mut attempt = 0u32;
        loop {
            let seq = next_seq(seq_last);
            stream.send(
                seq,
                MsgRef::Hello {
                    spec,
                    base_seed,
                    first_lane: first_lane as u64,
                    pipeline: opts.pipeline,
                    token: &opts.token,
                    wrap: &opts.wrap,
                    ctx: TraceCtx {
                        trace_id: opts.trace_id,
                        span_id: 0,
                    },
                },
            )?;
            seq_last = seq;
            let frame = stream.recv()?;
            let pre_parse_error =
                frame.seq == SEQ_NONE && matches!(frame.msg, Msg::Error { .. });
            if frame.seq != seq && !pre_parse_error {
                return Err(err(format!(
                    "{}: handshake reply sequence {} does not answer Hello {seq}",
                    parsed.render(),
                    frame.seq
                )));
            }
            match frame.msg {
                Msg::Spec { obs_dim, lane_specs } => {
                    return Ok(ShardClient {
                        stream,
                        addr: parsed.render(),
                        specs: lane_specs,
                        padded: obs_dim as usize,
                        seq_last,
                        pending: VecDeque::new(),
                        heartbeat: opts.heartbeat,
                        last_io: Instant::now(),
                        hb_sent: counter("cairl_heartbeats_sent_total"),
                        hb_missed: counter("cairl_heartbeats_missed_total"),
                    })
                }
                Msg::Busy {
                    active_lanes,
                    max_lanes,
                    retry_ms,
                } => {
                    // Cold path (handshake), so a registry lookup per
                    // retry is fine.
                    counter("cairl_shard_busy_retries_total").inc();
                    if attempt >= opts.busy_retries {
                        return Err(CairlError::Unavailable(format!(
                            "{}: lane budget exhausted ({active_lanes}/{max_lanes} lanes \
                             reserved) after {} Hello attempt(s)",
                            parsed.render(),
                            attempt + 1
                        )));
                    }
                    attempt += 1;
                    std::thread::sleep(Duration::from_millis(retry_ms.clamp(1, 1000)));
                }
                Msg::Error { message } => {
                    return Err(err(format!("{}: {message}", parsed.render())))
                }
                other => {
                    return Err(err(format!(
                        "{}: expected Spec after Hello, got {other:?}",
                        parsed.render()
                    )))
                }
            }
        }
    }

    /// The dialed address (canonical form).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The shard's per-lane metadata (shard-local offsets/padding).
    pub fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    /// The shard-local padded observation width.
    pub fn obs_dim(&self) -> usize {
        self.padded
    }

    /// Number of lanes hosted by this shard.
    pub fn num_lanes(&self) -> usize {
        self.specs.len()
    }

    /// Requests sent whose replies have not been received yet.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Attach a deterministic fault injector to this connection's send
    /// path (the `--chaos` machinery; see [`crate::faults`]).  Always
    /// attach **after** the handshake — and, under failover, after
    /// replay — so recovery itself is never sabotaged.
    pub fn attach_chaos(&mut self, profile: &ChaosProfile, stream: u64) {
        self.stream.set_fault_injector(Some(FaultPlan::new(profile, stream)));
    }

    /// Probe the connection with a `Ping`/`Pong` round trip.  Only
    /// valid with nothing in flight (the probe's reply would otherwise
    /// interleave with pending batch replies).  A failed probe counts
    /// into `cairl_heartbeats_missed_total` and means the connection is
    /// dead — pool callers fail over.
    pub fn ping(&mut self) -> Result<()> {
        if !self.pending.is_empty() {
            return Err(err(format!(
                "{}: ping with {} request(s) in flight",
                self.addr,
                self.pending.len()
            )));
        }
        let seq = next_seq(self.seq_last);
        let nonce = 0x6361_6972_0000_0000u64 | seq as u64;
        self.hb_sent.inc();
        let res = (|| -> Result<()> {
            self.stream.send(seq, MsgRef::Ping { nonce })?;
            self.seq_last = seq;
            let frame = self.stream.recv()?;
            if frame.seq != seq {
                return Err(err(format!(
                    "{}: pong sequence {} does not answer ping {seq}",
                    self.addr, frame.seq
                )));
            }
            match frame.msg {
                Msg::Pong { nonce: echoed } if echoed == nonce => Ok(()),
                other => Err(err(format!(
                    "{}: expected Pong({nonce}), got {other:?}",
                    self.addr
                ))),
            }
        })();
        match res {
            Ok(()) => {
                self.last_io = Instant::now();
                Ok(())
            }
            Err(e) => {
                self.hb_missed.inc();
                Err(e)
            }
        }
    }

    /// Fire an idle heartbeat if one is due ([`ConnectOptions::
    /// heartbeat`]): connection idle at least the interval, nothing in
    /// flight.  Called before every request so a long think-time gap
    /// can't hide a dead shard until the next batch is already at risk.
    fn maybe_heartbeat(&mut self) -> Result<()> {
        match self.heartbeat {
            Some(interval)
                if self.pending.is_empty() && self.last_io.elapsed() >= interval =>
            {
                self.ping()
            }
            _ => Ok(()),
        }
    }

    /// Stamp and send one request frame, recording its seq as pending.
    fn send_request(&mut self, msg: MsgRef<'_>) -> Result<()> {
        self.maybe_heartbeat()?;
        let seq = next_seq(self.seq_last);
        self.stream.send(seq, msg)?;
        self.seq_last = seq;
        self.pending.push_back(seq);
        self.last_io = Instant::now();
        Ok(())
    }

    /// Receive the reply to the oldest in-flight request, enforcing the
    /// seq echo, and return the reply's seq alongside the message so
    /// callers can tell a transport-level server `Error` (reserved seq
    /// 0: the daemon bailed before parsing a request — corruption or
    /// truncation, retryable via failover) from a deterministic
    /// request-level `Error` (echoed seq — never retried).
    fn recv_reply_seq(&mut self) -> Result<(u32, Msg)> {
        let expected = self
            .pending
            .front()
            .copied()
            .ok_or_else(|| err(format!("{}: no request in flight", self.addr)))?;
        let frame = self.stream.recv()?;
        if frame.seq != expected {
            // A pre-parse server error carries the reserved seq 0.
            if frame.seq == SEQ_NONE && matches!(frame.msg, Msg::Error { .. }) {
                self.pending.pop_front();
                return Ok((SEQ_NONE, frame.msg));
            }
            return Err(err(format!(
                "{}: reply sequence {} does not answer the oldest in-flight request {expected}",
                self.addr, frame.seq
            )));
        }
        self.pending.pop_front();
        self.last_io = Instant::now();
        Ok((frame.seq, frame.msg))
    }

    /// Receive the reply to the oldest in-flight request.  A server
    /// `Error` comes back as `Ok(Msg::Error)` — callers decide whether
    /// it is fatal.
    fn recv_reply(&mut self) -> Result<Msg> {
        self.recv_reply_seq().map(|(_, msg)| msg)
    }

    /// Receive one reply, surfacing a server `Error` frame as [`Err`].
    fn expect_reply(&mut self) -> Result<Msg> {
        match self.recv_reply()? {
            Msg::Error { message } => Err(err(format!("{}: {message}", self.addr))),
            msg => Ok(msg),
        }
    }

    /// Write a `Reset` frame (reply read by [`ShardClient::recv_obs`]).
    /// `ctx` is the v6 trace context ([`TraceCtx::NONE`] = untraced).
    pub fn send_reset(&mut self, ctx: TraceCtx) -> Result<()> {
        self.send_request(MsgRef::Reset { ctx })
    }

    /// Write a `Step` frame (reply read by [`ShardClient::recv_step`]).
    pub fn send_step(&mut self, actions: &[Action], ctx: TraceCtx) -> Result<()> {
        self.send_request(MsgRef::Step { actions, ctx })
    }

    /// Write a `RandomRollout` frame (reply read by
    /// [`ShardClient::recv_rollout`]).
    pub fn send_rollout(&mut self, steps_per_lane: u64, ctx: TraceCtx) -> Result<()> {
        self.send_request(MsgRef::RandomRollout { steps_per_lane, ctx })
    }

    /// Read an `Obs` reply (the server-timing block is dropped; the
    /// pool's pipelined receive path consumes it via its own helpers).
    pub fn recv_obs(&mut self) -> Result<Vec<f32>> {
        match self.expect_reply()? {
            Msg::Obs { obs, .. } => Ok(obs),
            other => Err(err(format!(
                "{}: expected Obs, got {other:?}",
                self.addr
            ))),
        }
    }

    /// Read a `StepResult` reply.
    pub fn recv_step(&mut self) -> Result<(Vec<f32>, Vec<Transition>)> {
        match self.expect_reply()? {
            Msg::StepResult { obs, transitions, .. } => Ok((obs, transitions)),
            other => Err(err(format!(
                "{}: expected StepResult, got {other:?}",
                self.addr
            ))),
        }
    }

    /// Read a `RolloutDone` reply.
    pub fn recv_rollout(&mut self) -> Result<RolloutCounts> {
        match self.expect_reply()? {
            Msg::RolloutDone { steps, episodes, .. } => Ok(RolloutCounts { steps, episodes }),
            other => Err(err(format!(
                "{}: expected RolloutDone, got {other:?}",
                self.addr
            ))),
        }
    }
}

impl Drop for ShardClient {
    fn drop(&mut self) {
        // Orderly hang-up; the daemon tolerates a plain disconnect too.
        let _ = self.stream.send(next_seq(self.seq_last), MsgRef::Close);
    }
}

/// Query a daemon's status report (the `cairl serve --status` path):
/// dial, send `Status`, return the JSON document.  Works without a
/// `Hello`, so it never reserves lanes.
pub fn shard_status(addr: &str, token: &str) -> Result<String> {
    let parsed = ShardAddr::parse(addr)?;
    let mut stream = FramedStream::connect(&parsed)?;
    stream.send(1, MsgRef::Status { token })?;
    let frame = stream.recv()?;
    match frame.msg {
        Msg::StatusReport { report } => Ok(report),
        Msg::Error { message } => Err(err(format!("{}: {message}", parsed.render()))),
        other => Err(err(format!(
            "{}: expected StatusReport, got {other:?}",
            parsed.render()
        ))),
    }
}

/// Flatten an env spec into mixture entries (a bare id contributes
/// `lanes` copies, mirroring
/// [`build_executor`](crate::coordinator::experiment::build_executor)).
fn entries_for(env_spec: &str, lanes: usize) -> Result<Vec<MixtureEntry>> {
    if MixtureSpec::is_mixture(env_spec) {
        Ok(MixtureSpec::parse(env_spec)?.entries().to_vec())
    } else {
        registry::validate(env_spec)?;
        Ok(vec![MixtureEntry::bare(env_spec, lanes.max(1))])
    }
}

/// Recovery policy when a shard connection is lost mid-workload.
#[derive(Clone, Debug)]
pub struct FailoverConfig {
    /// Re-dial attempts against the lost shard's own address before
    /// falling back to re-planning (`0` skips straight to re-planning,
    /// or — with [`FailoverConfig::replan`] off — disables failover).
    pub redial_attempts: u32,
    /// Initial back-off before the first re-dial, doubled per attempt.
    pub backoff_ms: u64,
    /// Back-off ceiling.
    pub backoff_cap_ms: u64,
    /// After re-dials are exhausted, offer the lost assignment to each
    /// surviving shard address in turn (their daemons host it as a new
    /// private executor).
    pub replan: bool,
}

impl Default for FailoverConfig {
    fn default() -> FailoverConfig {
        FailoverConfig {
            redial_attempts: 4,
            backoff_ms: 25,
            backoff_cap_ms: 400,
            replan: true,
        }
    }
}

/// Connection options for a [`ShardedEnvPool`].
#[derive(Clone, Debug)]
pub struct ShardPoolOptions {
    /// Lane count when the spec is a bare id (mixtures carry their own).
    pub lanes: usize,
    /// Pool-wide base seed (lane `i` is seeded `base_seed + i`
    /// wherever it lands).
    pub base_seed: u64,
    /// Outstanding batches per shard connection, clamped to
    /// `1..=`[`MAX_PIPELINE`].  Depth 1 is classic lockstep.
    pub pipeline: usize,
    /// Auth token forwarded on every handshake (`""` = none).
    pub token: String,
    /// `Busy` retries per handshake before
    /// [`CairlError::Unavailable`].
    pub busy_retries: u32,
    /// Pool-level wrapper chain applied server-side to every lane
    /// (`--wrap` grammar, e.g. `"TimeLimit(200),NormalizeObs"`; `""`
    /// defers to each daemon's configured default).  Forwarded verbatim
    /// in the `Hello` `wrap` field, including on failover re-dials.
    pub wrap: String,
    /// Per-id step costs for placement; `None` runs a calibration
    /// rollout at connect time ([`calibrate_costs`]).
    pub costs: Option<BTreeMap<String, f64>>,
    /// Recovery policy on connection loss.
    pub failover: FailoverConfig,
    /// Per-frame read deadline on every shard connection
    /// ([`ConnectOptions::read_timeout`]).  With failover enabled, a
    /// deadline turns a frozen shard into a bounded-latency failover
    /// instead of an indefinite stall.
    pub read_timeout: Option<Duration>,
    /// Per-frame write deadline ([`ConnectOptions::write_timeout`]).
    pub write_timeout: Option<Duration>,
    /// Idle heartbeat interval ([`ConnectOptions::heartbeat`]).
    pub heartbeat: Option<Duration>,
    /// Client-side chaos: a fault injector attached to every shard
    /// connection post-handshake (and re-attached after failover
    /// replay, on a fresh stream).  `None` or an `off` profile injects
    /// nothing.
    pub chaos: Option<ChaosProfile>,
}

impl Default for ShardPoolOptions {
    fn default() -> ShardPoolOptions {
        ShardPoolOptions {
            lanes: 1,
            base_seed: 0,
            pipeline: 1,
            token: String::new(),
            busy_retries: 4,
            wrap: String::new(),
            costs: None,
            failover: FailoverConfig::default(),
            read_timeout: None,
            write_timeout: None,
            heartbeat: None,
            chaos: None,
        }
    }
}

/// One replayable operation in a pool's lifetime.  Every variant is a
/// deterministic function of the connection's seeding origin — a random
/// rollout resets its lanes and draws from dedicated per-call streams
/// ([`crate::coordinator::pool::EnvPool::random_rollout`]) — so
/// replaying the full log against a fresh executor reconstructs lane
/// state bit-exactly.  Each op also keeps the trace context it was
/// first sent with: a failover replay re-sends the **original** span
/// ids (protocol v6 rule), so a replayed batch stays one node in the
/// trace instead of forking a phantom sibling.
enum ReplayOp {
    Reset { ctx: TraceCtx },
    /// The full global action batch (each shard replays its slice).
    /// Empty when failover is disabled — nothing will ever replay it.
    Step { actions: Vec<Action>, ctx: TraceCtx },
    Rollout { steps: u64, ctx: TraceCtx },
}

/// How a shard interaction failed, from the pool's perspective.
enum Fault {
    /// The connection is unusable (I/O error, EOF, frame corruption, a
    /// sequencing violation): failover may transparently rebuild it.
    Lost(String),
    /// The shard answered with a deterministic `Error` frame; replaying
    /// would reproduce it, so failover must not retry.
    Remote(String),
}

/// Receive one reply, classifying failures for the failover machinery.
/// An `Error` frame with the reserved seq 0 is a transport-level bail
/// (the daemon rejected an unparseable frame — corruption/truncation):
/// that is [`Fault::Lost`], because a fresh connection replaying the
/// log will not reproduce it.  An `Error` echoing a request seq is a
/// deterministic execution failure: [`Fault::Remote`], never retried.
fn recv_msg_fault(client: &mut ShardClient) -> std::result::Result<Msg, Fault> {
    match client.recv_reply_seq() {
        Ok((seq, Msg::Error { message })) => {
            let tagged = format!("{}: {message}", client.addr());
            if seq == SEQ_NONE {
                Err(Fault::Lost(tagged))
            } else {
                Err(Fault::Remote(tagged))
            }
        }
        Ok((_, msg)) => Ok(msg),
        Err(e) => Err(Fault::Lost(format!("{}: {e}", client.addr()))),
    }
}

fn recv_obs_fault(client: &mut ShardClient) -> std::result::Result<Vec<f32>, Fault> {
    match recv_msg_fault(client)? {
        Msg::Obs { obs, .. } => Ok(obs),
        other => Err(Fault::Lost(format!(
            "{}: expected Obs, got {other:?}",
            client.addr()
        ))),
    }
}

fn recv_step_fault(
    client: &mut ShardClient,
) -> std::result::Result<(Vec<f32>, Vec<Transition>, ServerTiming), Fault> {
    match recv_msg_fault(client)? {
        Msg::StepResult { obs, transitions, timing } => Ok((obs, transitions, timing)),
        other => Err(Fault::Lost(format!(
            "{}: expected StepResult, got {other:?}",
            client.addr()
        ))),
    }
}

fn recv_rollout_fault(client: &mut ShardClient) -> std::result::Result<RolloutCounts, Fault> {
    match recv_msg_fault(client)? {
        Msg::RolloutDone { steps, episodes, .. } => Ok(RolloutCounts { steps, episodes }),
        other => Err(Fault::Lost(format!(
            "{}: expected RolloutDone, got {other:?}",
            client.addr()
        ))),
    }
}

/// Record the `wire` span for one shard reply and synthesize the
/// server-side `decode` and `server_step` spans inside it.  The v6
/// reply reports **durations only** ([`ServerTiming`]) — client and
/// shard clocks are never compared — so the two remote spans are
/// centered in the observed wire window: whatever the window holds
/// beyond the reported server time splits evenly into outbound and
/// return flight.  All three parent under the batch span, carrying the
/// shard slot so the exporter can give each shard its own track.
fn record_remote_spans(
    trace_id: u64,
    batch_span: u64,
    shard: u32,
    lanes: u32,
    wire_start_ns: u64,
    wire_end_ns: u64,
    timing: ServerTiming,
) {
    let span = |kind, t_start_ns, t_end_ns| SpanRecord {
        span_id: trace::next_span_id(),
        parent: batch_span,
        trace_id,
        t_start_ns,
        t_end_ns,
        lane_group: lanes,
        shard,
        kind,
    };
    trace::record(span(SpanKind::Wire, wire_start_ns, wire_end_ns));
    let window = wire_end_ns.saturating_sub(wire_start_ns);
    let server = timing.decode_ns.saturating_add(timing.step_ns);
    let gap = window.saturating_sub(server) / 2;
    let decode_start = wire_start_ns + gap;
    let step_start = decode_start + timing.decode_ns;
    trace::record(span(SpanKind::Decode, decode_start, step_start));
    trace::record(span(SpanKind::ServerStep, step_start, step_start + timing.step_ns));
}

/// A [`BatchedExecutor`] whose lanes live on remote shards, with an
/// in-flight pipeline window and deterministic failover.
///
/// # Example: pipelined stepping against an in-process daemon
///
/// ```
/// use cairl::coordinator::pool::BatchedExecutor;
/// use cairl::shard::{ServeConfig, ShardPoolOptions, ShardServer, ShardedEnvPool};
///
/// let mut config = ServeConfig::new("CartPole-v1");
/// config.lanes = 2;
/// config.threads = 1;
/// let handle = ShardServer::bind("tcp://127.0.0.1:0", config).unwrap().spawn();
///
/// let addrs = vec![handle.addr().to_string()];
/// let opts = ShardPoolOptions {
///     lanes: 2,
///     base_seed: 7,
///     pipeline: 2,                       // keep 2 batches in flight
///     costs: Some(Default::default()),   // skip calibration
///     ..Default::default()
/// };
/// let mut pool = ShardedEnvPool::connect_opts(&addrs, "CartPole-v1", opts).unwrap();
/// assert_eq!(pool.pipeline_depth(), 2);
///
/// // Identical episode-return log to a local pool at any depth:
/// let result = pool.run_pipelined_workload(40, 7);
/// assert_eq!(result.steps, 80);
/// drop(pool);
/// handle.shutdown();
/// ```
pub struct ShardedEnvPool {
    clients: Vec<ShardClient>,
    plan: ShardPlan,
    specs: Vec<LaneSpec>,
    n: usize,
    padded: usize,
    /// Dial address per shard slot (updated when a slot re-plans onto a
    /// surviving daemon).
    addrs: Vec<String>,
    base_seed: u64,
    depth: usize,
    token: String,
    busy_retries: u32,
    wrap: String,
    failover: FailoverConfig,
    read_timeout: Option<Duration>,
    write_timeout: Option<Duration>,
    heartbeat: Option<Duration>,
    /// Client-side chaos profile; injectors are attached per connection
    /// on a fresh PCG stream (slot + reconnect generation).
    chaos: Option<ChaosProfile>,
    /// Replay log since connect; the failover source of truth.
    history: Vec<ReplayOp>,
    /// Per shard: ops from `history` sent on its current connection.
    ops_sent: Vec<usize>,
    /// Per shard: ops whose replies were consumed by the pool.
    ops_acked: Vec<usize>,
    /// Ops fully consumed across all shards (pool-level barrier index).
    ops_consumed: usize,
    reconnects: Vec<u64>,
    metrics: ExecMetrics,
    /// Trace id shared by every span this pool records (assigned
    /// lazily from [`trace::new_trace_id`] on the first traced op; `0`
    /// until then).  One pool = one stitched timeline.
    trace_id: u64,
    /// Per shard: wire-window start (ns) of in-flight traced `Step`
    /// ops on the *current* connection — the instant its request
    /// finished sending.  Cleared on failover alongside `sent_at`:
    /// replayed batches keep their span ids but report no wire spans.
    wire_start: Vec<VecDeque<u64>>,
    /// In-flight batches' `(batch_span_id, t_start_ns)`, pushed by
    /// [`ShardedEnvPool::submit_step`], popped by
    /// [`ShardedEnvPool::recv_oldest_step`].  Span id `0` = untraced
    /// batch; start `0` = untimed (metrics and tracing both off).
    batch_spans: VecDeque<(u64, u64)>,
    /// Per shard: send timestamps of in-flight `Step` ops on the
    /// *current* connection (cleared on failover, so a replayed op never
    /// reports a bogus round-trip).
    sent_at: Vec<VecDeque<Instant>>,
    /// Per shard: `cairl_shard_rtt_us{shard="s"}` round-trip histogram.
    m_rtt: Vec<Histogram>,
    /// Per shard: `cairl_shard_inflight{shard="s"}` occupancy gauge.
    m_inflight: Vec<Gauge>,
    /// `cairl_shard_reconnects_total` (re-dials plus re-plans).
    m_reconnects: Counter,
}

impl ShardedEnvPool {
    /// Connect to `addrs` with a cost-aware plan from a fresh
    /// calibration rollout ([`calibrate_costs`]); lockstep (depth-1)
    /// pipeline, default failover.
    pub fn connect(
        addrs: &[String],
        env_spec: &str,
        lanes: usize,
        base_seed: u64,
    ) -> Result<ShardedEnvPool> {
        Self::connect_opts(
            addrs,
            env_spec,
            ShardPoolOptions {
                lanes,
                base_seed,
                ..Default::default()
            },
        )
    }

    /// [`ShardedEnvPool::connect`] with explicit per-id costs — the
    /// deterministic entry point (tests, or operators pinning a known
    /// cost model instead of re-measuring at connect time).
    pub fn connect_with_costs(
        addrs: &[String],
        env_spec: &str,
        lanes: usize,
        base_seed: u64,
        costs: &BTreeMap<String, f64>,
    ) -> Result<ShardedEnvPool> {
        Self::connect_opts(
            addrs,
            env_spec,
            ShardPoolOptions {
                lanes,
                base_seed,
                costs: Some(costs.clone()),
                ..Default::default()
            },
        )
    }

    /// Connect with the full option set: pipeline depth, auth token,
    /// pinned costs and failover policy.
    pub fn connect_opts(
        addrs: &[String],
        env_spec: &str,
        opts: ShardPoolOptions,
    ) -> Result<ShardedEnvPool> {
        let entries = entries_for(env_spec, opts.lanes)?;
        // Fail fast on a malformed chain instead of letting every
        // daemon reject the handshake one by one.
        WrapperSpec::parse_chain(&opts.wrap)?;
        let costs = match &opts.costs {
            Some(costs) => costs.clone(),
            None => calibrate_costs(&entries)?,
        };
        Self::connect_planned(addrs, &entries, &costs, opts)
    }

    fn connect_planned(
        addrs: &[String],
        entries: &[MixtureEntry],
        costs: &BTreeMap<String, f64>,
        opts: ShardPoolOptions,
    ) -> Result<ShardedEnvPool> {
        if addrs.is_empty() {
            return Err(CairlError::Config(
                "a sharded pool needs at least one shard address".into(),
            ));
        }
        let depth = opts.pipeline.clamp(1, MAX_PIPELINE);
        let plan = ShardPlan::plan(entries, addrs.len(), costs)?;
        // A pool connected while tracing is live stamps its trace id
        // into every handshake; enabled later, the id is minted lazily
        // by the first traced op instead.
        let trace_id = if trace::enabled() { trace::new_trace_id() } else { 0 };
        let conn_opts = ConnectOptions {
            pipeline: depth as u32,
            token: opts.token.clone(),
            busy_retries: opts.busy_retries,
            wrap: opts.wrap.clone(),
            read_timeout: opts.read_timeout,
            write_timeout: opts.write_timeout,
            heartbeat: opts.heartbeat,
            trace_id,
        };
        let mut clients = Vec::with_capacity(addrs.len());
        for (addr, assignment) in addrs.iter().zip(plan.assignments()) {
            let client = ShardClient::connect_with(
                addr,
                &assignment.spec(),
                opts.base_seed,
                assignment.first_lane,
                &conn_opts,
            )?;
            if client.num_lanes() != assignment.lanes {
                return Err(err(format!(
                    "{addr}: hosts {} lanes, plan expected {}",
                    client.num_lanes(),
                    assignment.lanes
                )));
            }
            clients.push(client);
        }

        // Global layout: pool-wide padding is the widest lane anywhere;
        // offsets are recomputed in global lane order.
        let padded = clients
            .iter()
            .flat_map(|c| c.lane_specs())
            .map(|s| s.obs_dim)
            .max()
            .ok_or_else(|| err("sharded pool has no lanes"))?;
        let mut specs = Vec::with_capacity(plan.total_lanes());
        for (client, assignment) in clients.iter().zip(plan.assignments()) {
            for (j, spec) in client.lane_specs().iter().enumerate() {
                specs.push(LaneSpec {
                    env_id: spec.env_id.clone(),
                    obs_dim: spec.obs_dim,
                    offset: (assignment.first_lane + j) * padded,
                    action_space: spec.action_space.clone(),
                });
            }
        }
        let n = specs.len();
        let shards = clients.len();
        let mut pool = ShardedEnvPool {
            clients,
            plan,
            specs,
            n,
            padded,
            addrs: addrs.to_vec(),
            base_seed: opts.base_seed,
            depth,
            token: opts.token,
            busy_retries: opts.busy_retries,
            wrap: opts.wrap,
            failover: opts.failover,
            read_timeout: opts.read_timeout,
            write_timeout: opts.write_timeout,
            heartbeat: opts.heartbeat,
            chaos: opts.chaos,
            history: Vec::new(),
            ops_sent: vec![0; shards],
            ops_acked: vec![0; shards],
            ops_consumed: 0,
            reconnects: vec![0; shards],
            metrics: ExecMetrics::for_executor("shard"),
            trace_id,
            wire_start: (0..shards)
                .map(|_| VecDeque::with_capacity(MAX_PIPELINE))
                .collect(),
            batch_spans: VecDeque::with_capacity(MAX_PIPELINE),
            sent_at: (0..shards)
                .map(|_| VecDeque::with_capacity(MAX_PIPELINE))
                .collect(),
            m_rtt: (0..shards)
                .map(|s| {
                    histogram(&format!("cairl_shard_rtt_us{{shard=\"{s}\"}}"), &LATENCY_BOUNDS_US)
                })
                .collect(),
            m_inflight: (0..shards)
                .map(|s| gauge(&format!("cairl_shard_inflight{{shard=\"{s}\"}}")))
                .collect(),
            m_reconnects: counter("cairl_shard_reconnects_total"),
        };
        for s in 0..pool.clients.len() {
            pool.attach_chaos(s);
        }
        Ok(pool)
    }

    /// Arm the configured chaos injector on shard `s`'s current
    /// connection.  The PCG stream combines the slot and its reconnect
    /// generation, so a replacement connection draws a fresh (still
    /// deterministic) fault sequence instead of re-hitting the same
    /// faults at the same replay points forever.
    fn attach_chaos(&mut self, s: usize) {
        if let Some(profile) = &self.chaos {
            if !profile.is_off() {
                let stream = ((s as u64) << 32) | self.reconnects[s];
                self.clients[s].attach_chaos(profile, stream);
            }
        }
    }

    /// The placement this pool connected with.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Number of connected shards.
    pub fn shards(&self) -> usize {
        self.clients.len()
    }

    /// The configured in-flight window (1 = lockstep).
    pub fn pipeline_depth(&self) -> usize {
        self.depth
    }

    /// Batches submitted but not yet consumed.
    pub fn in_flight(&self) -> usize {
        self.history.len() - self.ops_consumed
    }

    /// Per-shard reconnect counts (re-dials plus re-plans) since
    /// connect — zero everywhere on a healthy fabric.
    pub fn reconnects(&self) -> &[u64] {
        &self.reconnects
    }

    /// Whether operations are logged for replay (on unless the failover
    /// policy can never act).
    fn failover_enabled(&self) -> bool {
        self.failover.redial_attempts > 0 || self.failover.replan
    }

    /// The `(first_lane, lanes)` slice owned by shard `s`.
    fn slice_of(&self, s: usize) -> (usize, usize) {
        let a = &self.plan.assignments()[s];
        (a.first_lane, a.lanes)
    }

    /// Reassemble one shard's observation block into the global
    /// `[n * padded]` buffer.  Since protocol v4 the wire block is
    /// **tail-elided**: each lane ships only its true (unpadded)
    /// observation back to back, so the block is `Σ lane obs_dim` floats
    /// — padding never crosses the wire.  The client re-pads: copy each
    /// lane's observation into its global slot and re-zero the tail.
    fn scatter_obs(&self, shard: usize, shard_obs: &[f32], obs: &mut [f32]) {
        let assignment = &self.plan.assignments()[shard];
        let client = &self.clients[shard];
        let expect: usize = client.lane_specs().iter().map(|s| s.obs_dim).sum();
        assert_eq!(
            shard_obs.len(),
            expect,
            "{}: short observation block",
            client.addr()
        );
        let mut cursor = 0usize;
        for j in 0..assignment.lanes {
            let width = client.lane_specs()[j].obs_dim;
            let src = &shard_obs[cursor..cursor + width];
            cursor += width;
            let base = (assignment.first_lane + j) * self.padded;
            obs[base..base + width].copy_from_slice(src);
            obs[base + width..base + self.padded].fill(0.0);
        }
    }

    /// Recover shard `s` after its connection was lost: bounded-backoff
    /// re-dials against its own address, then (if configured) offer its
    /// assignment to each surviving shard address.  Panics only when
    /// every candidate is exhausted — the executor traits have no error
    /// channel, and by then the fabric is truly gone.
    fn failover(&mut self, s: usize, cause: &str) {
        let assignment = self.plan.assignments()[s].clone();
        let own = self.addrs[s].clone();
        if !self.failover_enabled() {
            panic!("shard {s} ({own}) lost with failover disabled: {cause}");
        }
        eprintln!("cairl: shard {s} ({own}) lost ({cause}); recovering");
        let mut last = cause.to_string();
        let mut delay = self.failover.backoff_ms.max(1);
        for attempt in 0..self.failover.redial_attempts {
            std::thread::sleep(Duration::from_millis(delay));
            delay = delay.saturating_mul(2).min(self.failover.backoff_cap_ms.max(1));
            match self.dial_and_replay(&own, s, &assignment) {
                Ok(()) => {
                    eprintln!(
                        "cairl: shard {s} reconnected to {own} after {} attempt(s), \
                         replayed {} op(s)",
                        attempt + 1,
                        self.history.len()
                    );
                    return;
                }
                Err(e) => last = e.to_string(),
            }
        }
        if self.failover.replan {
            for j in 0..self.addrs.len() {
                if j == s || self.addrs[j] == own {
                    continue;
                }
                let candidate = self.addrs[j].clone();
                match self.dial_and_replay(&candidate, s, &assignment) {
                    Ok(()) => {
                        self.addrs[s] = candidate.clone();
                        eprintln!(
                            "cairl: shard {s} re-planned lanes {}..{} onto {candidate}",
                            assignment.first_lane,
                            assignment.first_lane + assignment.lanes
                        );
                        return;
                    }
                    Err(e) => last = e.to_string(),
                }
            }
        }
        panic!(
            "shard {s} ({own}) lost and unrecoverable after {} re-dial attempt(s){}: {last}",
            self.failover.redial_attempts,
            if self.failover.replan {
                " and re-planning across every surviving shard"
            } else {
                ""
            }
        );
    }

    /// Dial `addr` for shard slot `s` and replay the full operation log
    /// against its fresh private executor.  Replies for ops the pool
    /// already consumed are drained in send/recv lockstep; the unacked
    /// tail (at most the pipeline window) is left in flight for the
    /// caller to consume normally.
    fn dial_and_replay(&mut self, addr: &str, s: usize, a: &ShardAssignment) -> Result<()> {
        let conn_opts = ConnectOptions {
            pipeline: self.depth as u32,
            token: self.token.clone(),
            busy_retries: self.busy_retries,
            wrap: self.wrap.clone(),
            read_timeout: self.read_timeout,
            write_timeout: self.write_timeout,
            heartbeat: self.heartbeat,
            trace_id: self.trace_id,
        };
        let mut client =
            ShardClient::connect_with(addr, &a.spec(), self.base_seed, a.first_lane, &conn_opts)?;
        if client.lane_specs() != self.clients[s].lane_specs() {
            return Err(err(format!(
                "{addr}: replacement shard reported a different lane layout"
            )));
        }
        let acked = self.ops_acked[s];
        for (i, op) in self.history.iter().enumerate() {
            // Replays re-send each op's original trace context (v6
            // rule): the batch keeps its span ids across the failover.
            match op {
                ReplayOp::Reset { ctx } => client.send_reset(*ctx)?,
                ReplayOp::Step { actions, ctx } => {
                    client.send_step(&actions[a.first_lane..a.first_lane + a.lanes], *ctx)?
                }
                ReplayOp::Rollout { steps, ctx } => client.send_rollout(*steps, *ctx)?,
            }
            if i < acked {
                // The pool already consumed this op's result on the old
                // connection; drain and discard the replayed reply.
                match op {
                    ReplayOp::Reset { .. } => {
                        client.recv_obs()?;
                    }
                    ReplayOp::Step { .. } => {
                        client.recv_step()?;
                    }
                    ReplayOp::Rollout { .. } => {
                        client.recv_rollout()?;
                    }
                }
            }
        }
        self.clients[s] = client;
        self.ops_sent[s] = self.history.len();
        self.reconnects[s] += 1;
        self.m_reconnects.inc();
        // In-flight ops were re-sent by the replay; their round-trips
        // are no longer meaningful samples — and their wire windows are
        // gone with the old connection, so the replayed batches simply
        // report no wire/decode/server_step spans.
        self.sent_at[s].clear();
        self.wire_start[s].clear();
        // Chaos re-arms only now, after the replay — recovery itself
        // runs fault-free, so a replay can never be sabotaged into a
        // livelock by its own injector.
        self.attach_chaos(s);
        Ok(())
    }

    /// Probe every shard connection with a `Ping`/`Pong` round trip,
    /// transparently failing over any shard whose probe dies.  Only
    /// valid between batches (nothing in flight) — the idle-fleet
    /// health check for long think-time gaps.
    pub fn heartbeat(&mut self) {
        assert_eq!(
            self.in_flight(),
            0,
            "heartbeat while batches are in flight — drain the pipeline first"
        );
        for s in 0..self.clients.len() {
            loop {
                match self.clients[s].ping() {
                    Ok(()) => break,
                    Err(e) => {
                        let cause = format!("{}: {e}", self.clients[s].addr());
                        self.failover(s, &cause);
                    }
                }
            }
        }
    }

    /// Submit one global action batch without waiting for its result.
    /// Panics if the in-flight window ([`ShardedEnvPool::pipeline_depth`])
    /// is already full — call [`ShardedEnvPool::recv_oldest_step`] first.
    pub fn submit_step(&mut self, actions: &[Action]) {
        assert_eq!(actions.len(), self.n);
        assert!(
            self.in_flight() < self.depth,
            "pipeline window of {} batch(es) is full — recv_oldest_step first",
            self.depth
        );
        let tracing = trace::enabled();
        if tracing && self.trace_id == 0 {
            self.trace_id = trace::new_trace_id();
        }
        let batch_span = if tracing { trace::next_span_id() } else { 0 };
        let t_batch = if tracing || crate::telemetry::enabled() {
            trace::now_ns()
        } else {
            0
        };
        let ctx = if tracing {
            TraceCtx { trace_id: self.trace_id, span_id: batch_span }
        } else {
            TraceCtx::NONE
        };
        let logged = if self.failover_enabled() {
            actions.to_vec()
        } else {
            Vec::new()
        };
        self.history.push(ReplayOp::Step { actions: logged, ctx });
        let target = self.history.len();
        for s in 0..self.clients.len() {
            loop {
                if self.ops_sent[s] >= target {
                    break; // a failover replay already sent it
                }
                let (first, lanes) = self.slice_of(s);
                let t_encode = if tracing { trace::now_ns() } else { 0 };
                match self.clients[s].send_step(&actions[first..first + lanes], ctx) {
                    Ok(()) => {
                        self.ops_sent[s] += 1;
                        self.sent_at[s].push_back(Instant::now());
                        if tracing {
                            // Encode covers serialization + the write;
                            // the wire window opens where it closes.
                            let t_sent = trace::now_ns();
                            trace::record(SpanRecord {
                                span_id: trace::next_span_id(),
                                parent: batch_span,
                                trace_id: self.trace_id,
                                t_start_ns: t_encode,
                                t_end_ns: t_sent,
                                lane_group: lanes as u32,
                                shard: s as u32,
                                kind: SpanKind::Encode,
                            });
                            self.wire_start[s].push_back(t_sent);
                        }
                        self.m_inflight[s].set(self.clients[s].in_flight() as i64);
                        break;
                    }
                    Err(e) => {
                        let cause = format!("{}: {e}", self.clients[s].addr());
                        self.failover(s, &cause);
                    }
                }
            }
        }
        self.batch_spans.push_back((batch_span, t_batch));
    }

    /// Receive the oldest in-flight batch into `obs`/`transitions`
    /// (identical layout to [`BatchedExecutor::step_into`]).  Panics on
    /// a deterministic remote error; transparently fails over on a lost
    /// connection.
    pub fn recv_oldest_step(&mut self, obs: &mut [f32], transitions: &mut [Transition]) {
        assert!(
            self.in_flight() > 0,
            "recv_oldest_step with no batch in flight"
        );
        assert_eq!(obs.len(), self.n * self.padded);
        assert_eq!(transitions.len(), self.n);
        let idx = self.ops_consumed;
        debug_assert!(
            matches!(self.history[idx], ReplayOp::Step { .. }),
            "oldest unconsumed op is not a Step"
        );
        // Span id 0 = this batch was submitted untraced; the per-shard
        // wire_start queues then hold no entry for it either, so the
        // traced and untraced bookkeeping can never drift apart even if
        // the gate flips while batches are in flight.
        let (batch_span, t_batch) = self.batch_spans.pop_front().unwrap_or((0, 0));
        for s in 0..self.clients.len() {
            if self.ops_acked[s] > idx {
                continue;
            }
            loop {
                match recv_step_fault(&mut self.clients[s]) {
                    Ok((shard_obs, shard_tr, timing)) => {
                        let (first, lanes) = self.slice_of(s);
                        assert_eq!(
                            shard_tr.len(),
                            lanes,
                            "{}: short transition block",
                            self.clients[s].addr()
                        );
                        if batch_span != 0 {
                            let t_recv = trace::now_ns();
                            if let Some(w0) = self.wire_start[s].pop_front() {
                                record_remote_spans(
                                    self.trace_id,
                                    batch_span,
                                    s as u32,
                                    lanes as u32,
                                    w0,
                                    t_recv,
                                    timing,
                                );
                            }
                            self.scatter_obs(s, &shard_obs, obs);
                            transitions[first..first + lanes].copy_from_slice(&shard_tr);
                            trace::record(SpanRecord {
                                span_id: trace::next_span_id(),
                                parent: batch_span,
                                trace_id: self.trace_id,
                                t_start_ns: t_recv,
                                t_end_ns: trace::now_ns(),
                                lane_group: lanes as u32,
                                shard: s as u32,
                                kind: SpanKind::Reassemble,
                            });
                        } else {
                            self.scatter_obs(s, &shard_obs, obs);
                            transitions[first..first + lanes].copy_from_slice(&shard_tr);
                        }
                        self.ops_acked[s] = idx + 1;
                        // A failover replay cleared the timestamp queue;
                        // only samples from this connection count.
                        if let Some(t0) = self.sent_at[s].pop_front() {
                            self.m_rtt[s].record(t0.elapsed().as_micros() as u64);
                        }
                        self.m_inflight[s].set(self.clients[s].in_flight() as i64);
                        break;
                    }
                    Err(Fault::Remote(m)) => panic!("sharded step failed: {m}"),
                    Err(Fault::Lost(m)) => self.failover(s, &m),
                }
            }
        }
        self.ops_consumed += 1;
        let ends = transitions.iter().filter(|t| t.done || t.truncated).count();
        if t_batch != 0 {
            let t_end = trace::now_ns();
            if batch_span != 0 {
                trace::record(SpanRecord {
                    span_id: batch_span,
                    parent: 0,
                    trace_id: self.trace_id,
                    t_start_ns: t_batch,
                    t_end_ns: t_end,
                    lane_group: self.n as u32,
                    shard: trace::SHARD_LOCAL,
                    kind: SpanKind::Batch,
                });
            }
            // Satellite rule: the latency histogram derives from the
            // same timestamps as the batch span, so the two can't
            // disagree.
            self.metrics.record_batch_timed(self.n, ends, t_batch, t_end);
        } else {
            self.metrics.record_batch(self.n, ends);
        }
    }

    /// Run `steps_per_lane` random-action batches keeping up to the
    /// configured pipeline depth in flight.  Samples actions
    /// obs-independently in batch order — the exact RNG stream of
    /// [`run_batched_workload`](crate::coordinator::experiment::run_batched_workload)
    /// — so `episode_returns` is byte-identical to the lockstep driver
    /// on a local executor, at any depth, across failovers.
    pub fn run_pipelined_workload(&mut self, steps_per_lane: u64, seed: u64) -> SteppingResult {
        let n = self.n;
        let d = self.padded;
        let specs = self.specs.clone();
        let mut rng = Pcg32::new(seed, 23);
        let mut obs = vec![0.0f32; n * d];
        let mut transitions = vec![Transition::default(); n];
        let mut actions: Vec<Action> = Vec::with_capacity(n);
        self.reset_into(&mut obs);
        let mut episodes = 0u64;
        let mut episode_returns = Vec::new();
        let mut lane_return = vec![0.0f32; n];
        let start = Instant::now();
        let mut submitted = 0u64;
        let mut consumed = 0u64;
        while consumed < steps_per_lane {
            while submitted < steps_per_lane && self.in_flight() < self.depth {
                actions.clear();
                actions.extend(specs.iter().map(|s| s.action_space.sample(&mut rng)));
                self.submit_step(&actions);
                submitted += 1;
            }
            self.recv_oldest_step(&mut obs, &mut transitions);
            consumed += 1;
            for (acc, t) in lane_return.iter_mut().zip(&transitions) {
                *acc += t.reward;
                if t.done || t.truncated {
                    episodes += 1;
                    episode_returns.push(*acc);
                    *acc = 0.0;
                }
            }
        }
        let elapsed = start.elapsed();
        let steps = steps_per_lane * n as u64;
        SteppingResult {
            steps,
            episodes,
            elapsed,
            throughput: steps as f64 / elapsed.as_secs_f64(),
            episode_returns,
        }
    }
}

impl BatchedExecutor for ShardedEnvPool {
    fn num_lanes(&self) -> usize {
        self.n
    }

    fn obs_dim(&self) -> usize {
        self.padded
    }

    fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.n * self.padded);
        assert_eq!(
            self.in_flight(),
            0,
            "reset_into while batches are in flight — drain the pipeline first"
        );
        let tracing = trace::enabled();
        if tracing && self.trace_id == 0 {
            self.trace_id = trace::new_trace_id();
        }
        let reset_span = if tracing { trace::next_span_id() } else { 0 };
        let ctx = if tracing {
            TraceCtx { trace_id: self.trace_id, span_id: reset_span }
        } else {
            TraceCtx::NONE
        };
        let t_reset = if tracing { trace::now_ns() } else { 0 };
        self.history.push(ReplayOp::Reset { ctx });
        let target = self.history.len();
        // Write every shard's request before reading any reply: the
        // shards reset in parallel.
        for s in 0..self.clients.len() {
            loop {
                if self.ops_sent[s] >= target {
                    break;
                }
                match self.clients[s].send_reset(ctx) {
                    Ok(()) => {
                        self.ops_sent[s] += 1;
                        break;
                    }
                    Err(e) => {
                        let cause = format!("{}: {e}", self.clients[s].addr());
                        self.failover(s, &cause);
                    }
                }
            }
        }
        for s in 0..self.clients.len() {
            loop {
                if self.ops_acked[s] >= target {
                    break;
                }
                match recv_obs_fault(&mut self.clients[s]) {
                    Ok(shard_obs) => {
                        self.scatter_obs(s, &shard_obs, obs);
                        self.ops_acked[s] = target;
                        break;
                    }
                    Err(Fault::Remote(m)) => panic!("sharded reset failed: {m}"),
                    Err(Fault::Lost(m)) => self.failover(s, &m),
                }
            }
        }
        self.ops_consumed = target;
        if reset_span != 0 {
            trace::record(SpanRecord {
                span_id: reset_span,
                parent: 0,
                trace_id: self.trace_id,
                t_start_ns: t_reset,
                t_end_ns: trace::now_ns(),
                lane_group: self.n as u32,
                shard: trace::SHARD_LOCAL,
                kind: SpanKind::Reset,
            });
        }
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(
            self.in_flight(),
            0,
            "step_into while batches are in flight — use recv_oldest_step to drain"
        );
        self.submit_step(actions);
        self.recv_oldest_step(obs, transitions);
    }
}

impl RandomRollout for ShardedEnvPool {
    /// The free-running workload crosses the wire **once per shard**:
    /// every shard runs its whole rollout worker-side and reports
    /// aggregate counts.  Lane action streams are derived from the
    /// *global* base seed and lane ids (the shard knows its
    /// `first_lane`), so counts equal the local pool's bit for bit —
    /// and because a rollout resets its lanes and draws from dedicated
    /// per-call streams, it is itself a replayable operation under
    /// failover.
    fn random_rollout(&mut self, steps_per_lane: u64) -> RolloutCounts {
        assert_eq!(
            self.in_flight(),
            0,
            "random_rollout while batches are in flight — drain the pipeline first"
        );
        // A rollout runs entirely shard-side; it forwards the trace id
        // (span id 0 — no client-side parent batch) and records no
        // client spans of its own.
        let ctx = if trace::enabled() && self.trace_id != 0 {
            TraceCtx { trace_id: self.trace_id, span_id: 0 }
        } else {
            TraceCtx::NONE
        };
        self.history.push(ReplayOp::Rollout { steps: steps_per_lane, ctx });
        let target = self.history.len();
        for s in 0..self.clients.len() {
            loop {
                if self.ops_sent[s] >= target {
                    break;
                }
                match self.clients[s].send_rollout(steps_per_lane, ctx) {
                    Ok(()) => {
                        self.ops_sent[s] += 1;
                        break;
                    }
                    Err(e) => {
                        let cause = format!("{}: {e}", self.clients[s].addr());
                        self.failover(s, &cause);
                    }
                }
            }
        }
        let mut total = RolloutCounts::default();
        for s in 0..self.clients.len() {
            loop {
                if self.ops_acked[s] >= target {
                    break;
                }
                match recv_rollout_fault(&mut self.clients[s]) {
                    Ok(counts) => {
                        total.steps += counts.steps;
                        total.episodes += counts.episodes;
                        self.ops_acked[s] = target;
                        break;
                    }
                    Err(Fault::Remote(m)) => panic!("sharded rollout failed: {m}"),
                    Err(Fault::Lost(m)) => self.failover(s, &m),
                }
            }
        }
        self.ops_consumed = target;
        total
    }
}
