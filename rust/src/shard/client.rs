//! The client half: [`ShardClient`] (one connection) and
//! [`ShardedEnvPool`] (a [`BatchedExecutor`] over one or more remote
//! shards).
//!
//! A `ShardedEnvPool` is a drop-in executor: `lane_specs()`,
//! `obs_dim()`, `reset_into` and `step_into` behave identically to a
//! local pool over the same spec and seed — including **bit-identical
//! trajectories**, because each shard seeds its local lane `j` with
//! `base_seed + first_lane + j` (exactly the seed that lane holds
//! locally) and placement never reorders lanes
//! ([`ShardPlan`](crate::shard::plan::ShardPlan) cuts the lane list
//! contiguously at cost-balanced boundaries).
//!
//! Batches pipeline across shards: `step_into` writes every shard's
//! `Step` frame before reading any `StepResult`, so remote executors
//! step in parallel and the batch costs one round-trip to the slowest
//! shard, not the sum.
//!
//! **Padded-obs reassembly.**  Each shard pads observations to *its
//! own* widest lane; the pool-wide padded width can be larger (a shard
//! holding only `MountainCar-v0` lanes ships 2-wide rows into a 4-wide
//! pool).  Reassembly copies each lane's true observation into its
//! global slot and re-zeroes the tail, so mixture consumers see exactly
//! the local layout.
//!
//! Transport failures inside the `BatchedExecutor` surface as panics —
//! the same contract as a poisoned worker pool (the trait has no error
//! channel); connect-time problems return [`CairlError`] normally.

use std::collections::BTreeMap;

use crate::coordinator::pool::{BatchedExecutor, LaneSpec, RandomRollout, RolloutCounts};
use crate::coordinator::registry::{self, MixtureSpec};
use crate::core::env::Transition;
use crate::core::error::{CairlError, Result};
use crate::core::spaces::Action;
use crate::shard::net::{FramedStream, ShardAddr};
use crate::shard::plan::{calibrate_costs, ShardPlan};
use crate::shard::proto::{Msg, MsgRef};

fn err(msg: impl Into<String>) -> CairlError {
    CairlError::Shard(msg.into())
}

/// One framed connection to a shard daemon, post-handshake.
pub struct ShardClient {
    stream: FramedStream,
    addr: String,
    specs: Vec<LaneSpec>,
    padded: usize,
}

impl ShardClient {
    /// Dial `addr`, handshake with `spec` (`""` = the daemon's default)
    /// and the seeding origin, and return the connected client with the
    /// shard's lane metadata.
    pub fn connect(
        addr: &str,
        spec: &str,
        base_seed: u64,
        first_lane: usize,
    ) -> Result<ShardClient> {
        let parsed = ShardAddr::parse(addr)?;
        let mut stream = FramedStream::connect(&parsed)?;
        stream.send(MsgRef::Hello {
            spec,
            base_seed,
            first_lane: first_lane as u64,
        })?;
        match stream.recv()? {
            Msg::Spec { obs_dim, lane_specs } => Ok(ShardClient {
                stream,
                addr: parsed.render(),
                specs: lane_specs,
                padded: obs_dim as usize,
            }),
            Msg::Error { message } => Err(err(format!("{}: {message}", parsed.render()))),
            other => Err(err(format!(
                "{}: expected Spec after Hello, got {other:?}",
                parsed.render()
            ))),
        }
    }

    /// The dialed address (canonical form).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The shard's per-lane metadata (shard-local offsets/padding).
    pub fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    /// The shard-local padded observation width.
    pub fn obs_dim(&self) -> usize {
        self.padded
    }

    /// Number of lanes hosted by this shard.
    pub fn num_lanes(&self) -> usize {
        self.specs.len()
    }

    /// Receive one reply, surfacing a server `Error` frame as [`Err`].
    fn expect_reply(&mut self) -> Result<Msg> {
        match self.stream.recv()? {
            Msg::Error { message } => Err(err(format!("{}: {message}", self.addr))),
            msg => Ok(msg),
        }
    }

    /// Write a `Reset` frame (reply read by [`ShardClient::recv_obs`]).
    pub fn send_reset(&mut self) -> Result<()> {
        self.stream.send(MsgRef::Reset)
    }

    /// Write a `Step` frame (reply read by [`ShardClient::recv_step`]).
    pub fn send_step(&mut self, actions: &[Action]) -> Result<()> {
        self.stream.send(MsgRef::Step { actions })
    }

    /// Write a `RandomRollout` frame (reply read by
    /// [`ShardClient::recv_rollout`]).
    pub fn send_rollout(&mut self, steps_per_lane: u64) -> Result<()> {
        self.stream.send(MsgRef::RandomRollout { steps_per_lane })
    }

    /// Read an `Obs` reply.
    pub fn recv_obs(&mut self) -> Result<Vec<f32>> {
        match self.expect_reply()? {
            Msg::Obs { obs } => Ok(obs),
            other => Err(err(format!(
                "{}: expected Obs, got {other:?}",
                self.addr
            ))),
        }
    }

    /// Read a `StepResult` reply.
    pub fn recv_step(&mut self) -> Result<(Vec<f32>, Vec<Transition>)> {
        match self.expect_reply()? {
            Msg::StepResult { obs, transitions } => Ok((obs, transitions)),
            other => Err(err(format!(
                "{}: expected StepResult, got {other:?}",
                self.addr
            ))),
        }
    }

    /// Read a `RolloutDone` reply.
    pub fn recv_rollout(&mut self) -> Result<RolloutCounts> {
        match self.expect_reply()? {
            Msg::RolloutDone { steps, episodes } => Ok(RolloutCounts { steps, episodes }),
            other => Err(err(format!(
                "{}: expected RolloutDone, got {other:?}",
                self.addr
            ))),
        }
    }
}

impl Drop for ShardClient {
    fn drop(&mut self) {
        // Orderly hang-up; the daemon tolerates a plain disconnect too.
        let _ = self.stream.send(MsgRef::Close);
    }
}

/// Flatten an env spec into mixture entries (a bare id contributes
/// `lanes` copies, mirroring
/// [`build_executor`](crate::coordinator::experiment::build_executor)).
fn entries_for(env_spec: &str, lanes: usize) -> Result<Vec<(String, usize)>> {
    if MixtureSpec::is_mixture(env_spec) {
        Ok(MixtureSpec::parse(env_spec)?.entries().to_vec())
    } else {
        registry::validate(env_spec)?;
        Ok(vec![(env_spec.to_string(), lanes.max(1))])
    }
}

/// A [`BatchedExecutor`] whose lanes live on remote shards.
pub struct ShardedEnvPool {
    clients: Vec<ShardClient>,
    plan: ShardPlan,
    specs: Vec<LaneSpec>,
    n: usize,
    padded: usize,
}

impl ShardedEnvPool {
    /// Connect to `addrs` with a cost-aware plan from a fresh
    /// calibration rollout ([`calibrate_costs`]).
    pub fn connect(
        addrs: &[String],
        env_spec: &str,
        lanes: usize,
        base_seed: u64,
    ) -> Result<ShardedEnvPool> {
        let entries = entries_for(env_spec, lanes)?;
        let costs = calibrate_costs(&entries)?;
        Self::connect_planned(addrs, &entries, base_seed, &costs)
    }

    /// [`ShardedEnvPool::connect`] with explicit per-id costs — the
    /// deterministic entry point (tests, or operators pinning a known
    /// cost model instead of re-measuring at connect time).
    pub fn connect_with_costs(
        addrs: &[String],
        env_spec: &str,
        lanes: usize,
        base_seed: u64,
        costs: &BTreeMap<String, f64>,
    ) -> Result<ShardedEnvPool> {
        let entries = entries_for(env_spec, lanes)?;
        Self::connect_planned(addrs, &entries, base_seed, costs)
    }

    fn connect_planned(
        addrs: &[String],
        entries: &[(String, usize)],
        base_seed: u64,
        costs: &BTreeMap<String, f64>,
    ) -> Result<ShardedEnvPool> {
        if addrs.is_empty() {
            return Err(CairlError::Config(
                "a sharded pool needs at least one shard address".into(),
            ));
        }
        let plan = ShardPlan::plan(entries, addrs.len(), costs)?;
        let mut clients = Vec::with_capacity(addrs.len());
        for (addr, assignment) in addrs.iter().zip(plan.assignments()) {
            let client =
                ShardClient::connect(addr, &assignment.spec(), base_seed, assignment.first_lane)?;
            if client.num_lanes() != assignment.lanes {
                return Err(err(format!(
                    "{addr}: hosts {} lanes, plan expected {}",
                    client.num_lanes(),
                    assignment.lanes
                )));
            }
            clients.push(client);
        }

        // Global layout: pool-wide padding is the widest lane anywhere;
        // offsets are recomputed in global lane order.
        let padded = clients
            .iter()
            .flat_map(|c| c.lane_specs())
            .map(|s| s.obs_dim)
            .max()
            .ok_or_else(|| err("sharded pool has no lanes"))?;
        let mut specs = Vec::with_capacity(plan.total_lanes());
        for (client, assignment) in clients.iter().zip(plan.assignments()) {
            for (j, spec) in client.lane_specs().iter().enumerate() {
                specs.push(LaneSpec {
                    env_id: spec.env_id.clone(),
                    obs_dim: spec.obs_dim,
                    offset: (assignment.first_lane + j) * padded,
                    action_space: spec.action_space.clone(),
                });
            }
        }
        let n = specs.len();
        Ok(ShardedEnvPool {
            clients,
            plan,
            specs,
            n,
            padded,
        })
    }

    /// The placement this pool connected with.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Number of connected shards.
    pub fn shards(&self) -> usize {
        self.clients.len()
    }

    /// Reassemble one shard's `[lanes * shard_padded]` block into the
    /// global `[n * padded]` buffer: copy each lane's true observation,
    /// re-zero the global tail.
    fn scatter_obs(&self, shard: usize, shard_obs: &[f32], obs: &mut [f32]) {
        let assignment = &self.plan.assignments()[shard];
        let client = &self.clients[shard];
        let local_padded = client.obs_dim();
        assert_eq!(
            shard_obs.len(),
            assignment.lanes * local_padded,
            "{}: short observation block",
            client.addr()
        );
        for j in 0..assignment.lanes {
            let width = client.lane_specs()[j].obs_dim;
            let src = &shard_obs[j * local_padded..j * local_padded + width];
            let base = (assignment.first_lane + j) * self.padded;
            obs[base..base + width].copy_from_slice(src);
            obs[base + width..base + self.padded].fill(0.0);
        }
    }
}

impl BatchedExecutor for ShardedEnvPool {
    fn num_lanes(&self) -> usize {
        self.n
    }

    fn obs_dim(&self) -> usize {
        self.padded
    }

    fn lane_specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    fn reset_into(&mut self, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.n * self.padded);
        // Write every shard's request before reading any reply: the
        // shards reset in parallel.
        for client in &mut self.clients {
            client
                .send_reset()
                .unwrap_or_else(|e| panic!("sharded reset failed: {e}"));
        }
        for shard in 0..self.clients.len() {
            let shard_obs = self.clients[shard]
                .recv_obs()
                .unwrap_or_else(|e| panic!("sharded reset failed: {e}"));
            self.scatter_obs(shard, &shard_obs, obs);
        }
    }

    fn step_into(
        &mut self,
        actions: &[Action],
        obs: &mut [f32],
        transitions: &mut [Transition],
    ) {
        assert_eq!(actions.len(), self.n);
        assert_eq!(obs.len(), self.n * self.padded);
        assert_eq!(transitions.len(), self.n);
        for (client, assignment) in self.clients.iter_mut().zip(self.plan.assignments()) {
            let slice = &actions[assignment.first_lane..assignment.first_lane + assignment.lanes];
            client
                .send_step(slice)
                .unwrap_or_else(|e| panic!("sharded step failed: {e}"));
        }
        for shard in 0..self.clients.len() {
            let (shard_obs, shard_tr) = self.clients[shard]
                .recv_step()
                .unwrap_or_else(|e| panic!("sharded step failed: {e}"));
            let assignment = &self.plan.assignments()[shard];
            assert_eq!(
                shard_tr.len(),
                assignment.lanes,
                "{}: short transition block",
                self.clients[shard].addr()
            );
            self.scatter_obs(shard, &shard_obs, obs);
            transitions[assignment.first_lane..assignment.first_lane + assignment.lanes]
                .copy_from_slice(&shard_tr);
        }
    }
}

impl RandomRollout for ShardedEnvPool {
    /// The free-running workload crosses the wire **once per shard**:
    /// every shard runs its whole rollout worker-side and reports
    /// aggregate counts.  Lane action streams are derived from the
    /// *global* base seed and lane ids (the shard knows its
    /// `first_lane`), so counts equal the local pool's bit for bit.
    fn random_rollout(&mut self, steps_per_lane: u64) -> RolloutCounts {
        for client in &mut self.clients {
            client
                .send_rollout(steps_per_lane)
                .unwrap_or_else(|e| panic!("sharded rollout failed: {e}"));
        }
        let mut total = RolloutCounts::default();
        for client in &mut self.clients {
            let counts = client
                .recv_rollout()
                .unwrap_or_else(|e| panic!("sharded rollout failed: {e}"));
            total.steps += counts.steps;
            total.episodes += counts.episodes;
        }
        total
    }
}
