//! The DQN agent (Table I): epsilon-greedy exploration, replay,
//! target-network sync, and the environment training loop.
//!
//! All coordination is Rust; all numerics are the AOT artifacts.  This
//! is the agent behind Fig. 2 (training wall-clock on classic control),
//! Fig. 3 (Multitask) and Table II (carbon accounting).

use std::time::{Duration, Instant};

use crate::agents::replay::ReplayBuffer;
use crate::coordinator::pool::BatchedExecutor;
use crate::core::env::{Env, Transition};
use crate::core::error::Result;
use crate::core::rng::Pcg32;
use crate::core::spaces::{Action, Space};
use crate::runtime::dqn_exec::{Batch, DqnExecutor};
use crate::runtime::Runtime;

/// Training-loop hyperparameters (network/optimiser hyperparameters are
/// baked into the artifacts; these are the coordination knobs).
#[derive(Clone, Debug)]
pub struct DqnConfig {
    /// Table I: exploration start.
    pub epsilon_start: f32,
    /// Table I: exploration final.
    pub epsilon_final: f32,
    /// Steps over which epsilon anneals linearly.
    pub epsilon_decay_steps: u32,
    /// Table I: target update frequency (train steps).
    pub target_update_freq: u32,
    /// Table I: replay memory size.
    pub memory_size: usize,
    /// Environment steps before learning starts.
    pub learn_start: usize,
    /// Train every N environment steps.
    pub train_every: u32,
    /// Hard cap on environment steps.
    pub max_steps: u32,
    /// Solve criterion: mean return over `solve_window` episodes.
    pub solve_return: f32,
    pub solve_window: usize,
    /// RNG seed (exploration + replay sampling + env).
    pub seed: u64,
    /// Greedy-action path: native host forward (default; SSPerf fast
    /// path, numerically pinned to the artifact) or the PJRT act
    /// artifact (for strict artifact-only execution).
    pub native_act: bool,
}

impl Default for DqnConfig {
    fn default() -> Self {
        DqnConfig {
            epsilon_start: 1.0,
            epsilon_final: 0.01,
            epsilon_decay_steps: 5_000,
            target_update_freq: 150,
            memory_size: 50_000,
            learn_start: 500,
            train_every: 1,
            max_steps: 50_000,
            solve_return: 195.0,
            solve_window: 20,
            seed: 0,
            native_act: true,
        }
    }
}

/// A point on the training curve.
#[derive(Clone, Copy, Debug)]
pub struct EpisodePoint {
    pub env_steps: u32,
    pub ret: f32,
    pub len: u32,
}

/// Result of one training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// Solve criterion reached before `max_steps`.
    pub solved: bool,
    pub env_steps: u32,
    pub train_steps: u64,
    pub episodes: u32,
    pub wall_time: Duration,
    /// Per-episode returns in order.
    pub curve: Vec<EpisodePoint>,
    /// Loss every 100 train steps.
    pub losses: Vec<f32>,
    /// Final sliding-window mean return.
    pub final_mean_return: f32,
}

/// The DQN agent.
pub struct DqnAgent {
    pub exec: DqnExecutor,
    pub config: DqnConfig,
    replay: ReplayBuffer,
    rng: Pcg32,
}

impl DqnAgent {
    pub fn new(rt: &Runtime, env_name: &str, config: DqnConfig) -> Result<DqnAgent> {
        let exec = DqnExecutor::new(rt, env_name, config.seed)?;
        let replay = ReplayBuffer::new(config.memory_size, exec.obs_dim);
        let rng = Pcg32::new(config.seed, 0x8f14e45fceea167a);
        Ok(DqnAgent {
            exec,
            config,
            replay,
            rng,
        })
    }

    /// Linear epsilon at a given environment step.
    pub fn epsilon(&self, step: u32) -> f32 {
        let c = &self.config;
        if step >= c.epsilon_decay_steps {
            return c.epsilon_final;
        }
        let frac = step as f32 / c.epsilon_decay_steps as f32;
        c.epsilon_start + (c.epsilon_final - c.epsilon_start) * frac
    }

    /// Epsilon-greedy action for `obs` at environment step `step`.
    pub fn select_action(
        &mut self,
        rt: &mut Runtime,
        obs: &[f32],
        step: u32,
    ) -> Result<usize> {
        if self.rng.chance(self.epsilon(step)) {
            Ok(self.rng.below(self.exec.n_actions as u32) as usize)
        } else if self.config.native_act {
            Ok(self.exec.act_greedy_native(obs))
        } else {
            self.exec.act_greedy(rt, obs)
        }
    }

    /// Train on `env` until the solve criterion or the step cap.
    ///
    /// The loop is the paper's protocol: episodic interaction, replay
    /// learning every `train_every` steps once `learn_start` transitions
    /// exist, target sync every `target_update_freq` *train* steps.
    pub fn train<E: Env + ?Sized>(
        &mut self,
        rt: &mut Runtime,
        env: &mut E,
    ) -> Result<TrainOutcome> {
        let start = Instant::now();
        env.seed(self.config.seed);
        let dim = self.exec.obs_dim;
        assert_eq!(
            dim,
            env.obs_dim(),
            "artifact obs_dim must match the environment"
        );
        let mut obs = vec![0.0f32; dim];
        let mut next_obs = vec![0.0f32; dim];
        env.reset_into(&mut obs);

        let mut batch = Batch::default();
        let mut curve = Vec::new();
        let mut losses = Vec::new();
        let mut window: Vec<f32> = Vec::new();
        let mut ep_ret = 0.0f32;
        let mut ep_len = 0u32;
        let mut episodes = 0u32;
        let mut solved = false;
        let mut step = 0u32;

        while step < self.config.max_steps {
            let a = self.select_action(rt, &obs, step)?;
            let t = env.step_into(&Action::Discrete(a), &mut next_obs);
            step += 1;
            ep_ret += t.reward;
            ep_len += 1;
            // Truncation is not termination: bootstrap through it.
            self.replay
                .push(&obs, a, t.reward, &next_obs, t.done && !t.truncated);
            std::mem::swap(&mut obs, &mut next_obs);

            if self.replay.len() >= self.config.learn_start
                && step % self.config.train_every == 0
            {
                self.replay
                    .sample_into(&mut self.rng, self.exec.batch_size, &mut batch);
                let loss = self.exec.train_step(rt, &batch)?;
                if self.exec.steps % 100 == 0 {
                    losses.push(loss);
                }
                if self.exec.steps % self.config.target_update_freq as u64 == 0 {
                    self.exec.sync_target();
                }
            }

            if t.done || t.truncated {
                curve.push(EpisodePoint {
                    env_steps: step,
                    ret: ep_ret,
                    len: ep_len,
                });
                episodes += 1;
                window.push(ep_ret);
                if window.len() > self.config.solve_window {
                    window.remove(0);
                }
                if window.len() == self.config.solve_window {
                    let mean = window.iter().sum::<f32>() / window.len() as f32;
                    if mean >= self.config.solve_return {
                        solved = true;
                        break;
                    }
                }
                ep_ret = 0.0;
                ep_len = 0;
                env.reset_into(&mut obs);
            }
        }

        let final_mean_return = if window.is_empty() {
            f32::NEG_INFINITY
        } else {
            window.iter().sum::<f32>() / window.len() as f32
        };
        Ok(TrainOutcome {
            solved,
            env_steps: step,
            train_steps: self.exec.steps,
            episodes,
            wall_time: start.elapsed(),
            curve,
            losses,
            final_mean_return,
        })
    }
}

/// Outcome of a batched greedy policy evaluation.
#[derive(Clone, Debug)]
pub struct BatchedEvalOutcome {
    /// Total lane-steps executed (`steps_per_lane * lanes`).
    pub lane_steps: u64,
    /// Episodes that finished during the evaluation window.
    pub episodes: u64,
    /// Mean return over the finished episodes (`NaN` when none finished).
    pub mean_return: f32,
    pub wall_time: Duration,
}

/// Evaluate the executor's greedy policy over any [`BatchedExecutor`] —
/// the batched counterpart of running `act_greedy_native` in a single-env
/// loop, and the hook that lets evaluation flip between `VecEnv` and the
/// `EnvPool` executors via config.
///
/// Uses the native host forward only, so it works without a PJRT runtime
/// (the network weights already live host-side).  Lane episode returns
/// are accumulated per lane and recorded once at each episode end
/// (auto-reset keeps every lane live for the whole window).
///
/// Scenario-mixture pools are supported as long as every lane is
/// network-compatible: each lane's true `obs_dim` must equal the
/// network's input width and each lane's action space must be discrete,
/// accepting every action index the network can emit (validated against
/// [`BatchedExecutor::lane_specs`] — e.g. `CartPole-v1` mixed with
/// `Script/CartPole-v1` evaluates one policy across both runners).
/// Since every lane is full-width, the padded batch buffer degenerates
/// to the unpadded layout and feeds the batched forward directly.
pub fn evaluate_greedy_batched(
    exec: &DqnExecutor,
    pool: &mut dyn BatchedExecutor,
    steps_per_lane: u32,
) -> BatchedEvalOutcome {
    let n = pool.num_lanes();
    let d = pool.obs_dim();
    for spec in pool.lane_specs() {
        assert_eq!(
            spec.obs_dim, exec.obs_dim,
            "lane env {} obs_dim must match the network input",
            spec.env_id
        );
        match &spec.action_space {
            Space::Discrete { n } => assert!(
                *n >= exec.n_actions,
                "lane env {} accepts {} actions but the network may emit any of {}",
                spec.env_id,
                n,
                exec.n_actions
            ),
            Space::Box { .. } => {
                panic!("lane env {} is continuous; DQN is discrete", spec.env_id)
            }
        }
    }
    // Every lane is full-width, so padded == unpadded.
    assert_eq!(d, exec.obs_dim, "network obs_dim must match the lanes");
    let start = Instant::now();
    let mut obs = vec![0.0f32; n * d];
    let mut transitions = vec![Transition::default(); n];
    let mut greedy = vec![0usize; n];
    let mut actions: Vec<Action> = Vec::with_capacity(n);
    let mut lane_return = vec![0.0f32; n];
    let mut finished_sum = 0.0f64;
    let mut episodes = 0u64;
    pool.reset_into(&mut obs);
    for _ in 0..steps_per_lane {
        exec.act_greedy_batch_native(&obs, &mut greedy);
        actions.clear();
        actions.extend(greedy.iter().map(|&a| Action::Discrete(a)));
        pool.step_into(&actions, &mut obs, &mut transitions);
        for (acc, t) in lane_return.iter_mut().zip(&transitions) {
            *acc += t.reward;
            if t.done || t.truncated {
                finished_sum += *acc as f64;
                episodes += 1;
                *acc = 0.0;
            }
        }
    }
    BatchedEvalOutcome {
        lane_steps: steps_per_lane as u64 * n as u64,
        episodes,
        mean_return: if episodes == 0 {
            f32::NAN
        } else {
            (finished_sum / episodes as f64) as f32
        },
        wall_time: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_anneals_linearly() {
        let cfg = DqnConfig {
            epsilon_start: 1.0,
            epsilon_final: 0.0,
            epsilon_decay_steps: 100,
            ..Default::default()
        };
        // Build without a runtime: epsilon() is pure config math, so test
        // it via a structless copy of the formula on the config.
        let eps = |step: u32| {
            if step >= cfg.epsilon_decay_steps {
                cfg.epsilon_final
            } else {
                cfg.epsilon_start
                    + (cfg.epsilon_final - cfg.epsilon_start)
                        * (step as f32 / cfg.epsilon_decay_steps as f32)
            }
        };
        assert_eq!(eps(0), 1.0);
        assert!((eps(50) - 0.5).abs() < 1e-6);
        assert_eq!(eps(100), 0.0);
        assert_eq!(eps(10_000), 0.0);
    }

    #[test]
    fn default_config_matches_table_one() {
        let c = DqnConfig::default();
        assert_eq!(c.memory_size, 50_000);
        assert_eq!(c.target_update_freq, 150);
        assert_eq!(c.epsilon_start, 1.0);
        assert_eq!(c.epsilon_final, 0.01);
    }

    // Training-loop behaviour requires a PJRT runtime; covered by
    // rust/tests/dqn_integration.rs and examples/dqn_cartpole.rs.

    #[test]
    fn batched_greedy_eval_runs_on_every_executor_kind() {
        use crate::coordinator::experiment::{build_executor, ExecutorKind};
        use crate::runtime::dqn_exec::DqnExecutor;

        // No artifacts needed: `from_spec` + the native forward.
        let exec = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 5);
        let mut outcomes = Vec::new();
        for kind in [
            ExecutorKind::Sequential,
            ExecutorKind::PoolSync,
            ExecutorKind::PoolAsync,
        ] {
            let mut pool = build_executor("CartPole-v1", kind, 4, 2, 123).unwrap();
            let out = evaluate_greedy_batched(&exec, pool.as_mut(), 120);
            assert_eq!(out.lane_steps, 4 * 120, "{kind:?}");
            assert!(out.episodes > 0, "{kind:?}: greedy cartpole must end");
            assert!(out.mean_return.is_finite(), "{kind:?}");
            outcomes.push((out.episodes, out.mean_return));
        }
        // Deterministic policy + deterministic lanes: identical numbers
        // on every executor.
        assert_eq!(outcomes[0], outcomes[1]);
        assert_eq!(outcomes[0], outcomes[2]);
    }

    #[test]
    fn batched_greedy_eval_handles_scenario_mixtures() {
        use crate::coordinator::experiment::{build_executor, ExecutorKind};
        use crate::runtime::dqn_exec::DqnExecutor;

        // One 4-input/2-action network across native and script-runner
        // cart-pole lanes in the same pool (both are obs_dim 4, 2
        // actions, so every lane is network-compatible).
        let exec = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 5);
        let mut outcomes = Vec::new();
        for kind in [
            ExecutorKind::Sequential,
            ExecutorKind::PoolSync,
            ExecutorKind::PoolAsync,
        ] {
            let mut pool = build_executor(
                "CartPole-v1:2,Script/CartPole-v1:2",
                kind,
                1,
                2,
                123,
            )
            .unwrap();
            let out = evaluate_greedy_batched(&exec, pool.as_mut(), 80);
            assert_eq!(out.lane_steps, 4 * 80, "{kind:?}");
            assert!(out.episodes > 0, "{kind:?}: greedy cartpole must end");
            assert!(out.mean_return.is_finite(), "{kind:?}");
            outcomes.push((out.episodes, out.mean_return));
        }
        assert_eq!(outcomes[0], outcomes[1]);
        assert_eq!(outcomes[0], outcomes[2]);
    }

    #[test]
    #[should_panic(expected = "obs_dim must match the network input")]
    fn batched_greedy_eval_rejects_incompatible_lanes() {
        use crate::coordinator::experiment::{build_executor, ExecutorKind};
        use crate::runtime::dqn_exec::DqnExecutor;

        let exec = DqnExecutor::from_spec("cartpole", 4, 2, 32, 32, 5);
        // MountainCar lanes are obs_dim 2: the network can't read them.
        let mut pool = build_executor(
            "CartPole-v1:2,MountainCar-v0:2",
            ExecutorKind::Sequential,
            1,
            1,
            0,
        )
        .unwrap();
        let _ = evaluate_greedy_batched(&exec, pool.as_mut(), 10);
    }
}
