//! Learning agents — the algorithms the paper's evaluation trains
//! (§II-A, §V-B): DQN (Table I) plus a tabular Q-learning and a random
//! baseline.
//!
//! The DQN agent is pure coordination: the replay buffer, the epsilon
//! schedule, the target-sync cadence and the environment loop live here
//! in Rust; every gradient flows through the AOT artifact
//! ([`crate::runtime::DqnExecutor`]).

pub mod dqn;
pub mod qtable;
pub mod random;
pub mod replay;

pub use dqn::{
    evaluate_greedy_batched, BatchedEvalOutcome, DqnAgent, DqnConfig, TrainOutcome,
};
pub use qtable::QTableAgent;
pub use random::RandomAgent;
pub use replay::ReplayBuffer;
