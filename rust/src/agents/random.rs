//! Uniform-random agent — the evaluation floor every learner must beat,
//! and the workload driver for the Fig.-1 stepping benchmarks.

use crate::core::env::Env;
use crate::core::rng::Pcg32;
use crate::core::spaces::Space;

/// Samples uniformly from the action space every step.
pub struct RandomAgent {
    space: Space,
    rng: Pcg32,
}

impl RandomAgent {
    pub fn new(space: Space, seed: u64) -> RandomAgent {
        RandomAgent {
            space,
            rng: Pcg32::new(seed, 0xbf58476d1ce4e5b9),
        }
    }

    /// For an environment (reads its action space).
    pub fn for_env<E: Env + ?Sized>(env: &E, seed: u64) -> RandomAgent {
        RandomAgent::new(env.action_space(), seed)
    }

    /// Next random action.
    pub fn act(&mut self) -> crate::core::spaces::Action {
        self.space.sample(&mut self.rng)
    }

    /// Run `episodes` episodes, returning the mean return.
    pub fn evaluate<E: Env + ?Sized>(
        &mut self,
        env: &mut E,
        episodes: u32,
        cap: u32,
    ) -> f32 {
        let mut total = 0.0;
        for _ in 0..episodes {
            let (ret, _) =
                crate::core::env::random_rollout(env, &mut self.rng, cap);
            total += ret;
        }
        total / episodes as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::CartPole;

    #[test]
    fn acts_within_space() {
        let mut agent = RandomAgent::new(Space::Discrete { n: 3 }, 0);
        for _ in 0..100 {
            match agent.act() {
                crate::core::spaces::Action::Discrete(i) => assert!(i < 3),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn evaluate_returns_mean() {
        let mut env = CartPole::new();
        env.seed(0);
        let mut agent = RandomAgent::for_env(&env, 1);
        let mean = agent.evaluate(&mut env, 20, 500);
        // Random CartPole lives ~10-70 steps at 1 reward per step.
        assert!((5.0..100.0).contains(&mean), "{mean}");
    }
}
