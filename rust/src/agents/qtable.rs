//! Tabular Q-learning (§II-A) over a uniform state discretisation.
//!
//! The paper introduces Q-learning before DQN; the tabular agent doubles
//! as a runtime-free baseline (no PJRT needed), which the benchmarks use
//! to isolate environment cost from artifact-execution cost.

use crate::core::env::Env;
use crate::core::rng::Pcg32;
use crate::core::spaces::Action;

/// Q-learning with per-dimension uniform binning.
pub struct QTableAgent {
    bins: usize,
    lows: Vec<f32>,
    highs: Vec<f32>,
    n_actions: usize,
    /// Flat table: `bins^obs_dim * n_actions` entries.
    q: Vec<f32>,
    pub alpha: f32,
    pub gamma: f32,
    pub epsilon: f32,
    rng: Pcg32,
}

impl QTableAgent {
    /// `lows`/`highs` bound each observation dimension (clamped).
    pub fn new(
        bins: usize,
        lows: Vec<f32>,
        highs: Vec<f32>,
        n_actions: usize,
        seed: u64,
    ) -> QTableAgent {
        assert_eq!(lows.len(), highs.len());
        let states = bins.pow(lows.len() as u32);
        QTableAgent {
            bins,
            lows,
            highs,
            n_actions,
            q: vec![0.0; states * n_actions],
            alpha: 0.1,
            gamma: 0.99,
            epsilon: 0.1,
            rng: Pcg32::new(seed, 0xa3ec647659359acd),
        }
    }

    /// Map an observation to a flat state index.
    pub fn state_of(&self, obs: &[f32]) -> usize {
        let mut idx = 0usize;
        for (i, &o) in obs.iter().enumerate() {
            let lo = self.lows[i];
            let hi = self.highs[i];
            let clipped = o.clamp(lo, hi - 1e-6);
            let bin = ((clipped - lo) / (hi - lo) * self.bins as f32) as usize;
            idx = idx * self.bins + bin.min(self.bins - 1);
        }
        idx
    }

    fn row(&self, state: usize) -> &[f32] {
        &self.q[state * self.n_actions..(state + 1) * self.n_actions]
    }

    /// Greedy action (ties broken by lowest index).
    pub fn greedy(&self, state: usize) -> usize {
        let row = self.row(state);
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Epsilon-greedy action.
    pub fn select(&mut self, state: usize) -> usize {
        if self.rng.chance(self.epsilon) {
            self.rng.below(self.n_actions as u32) as usize
        } else {
            self.greedy(state)
        }
    }

    /// One Q-learning update.
    pub fn update(&mut self, s: usize, a: usize, r: f32, s2: usize, done: bool) {
        let max_next = if done {
            0.0
        } else {
            self.row(s2).iter().fold(f32::MIN, |m, &v| m.max(v))
        };
        let idx = s * self.n_actions + a;
        let target = r + self.gamma * max_next;
        self.q[idx] += self.alpha * (target - self.q[idx]);
    }

    /// Run one training episode; returns (return, length).
    pub fn train_episode<E: Env + ?Sized>(&mut self, env: &mut E, cap: u32) -> (f32, u32) {
        let dim = env.obs_dim();
        let mut obs = vec![0.0f32; dim];
        let mut next = vec![0.0f32; dim];
        env.reset_into(&mut obs);
        let mut s = self.state_of(&obs);
        let mut ret = 0.0;
        let mut len = 0;
        while len < cap {
            let a = self.select(s);
            let t = env.step_into(&Action::Discrete(a), &mut next);
            let s2 = self.state_of(&next);
            self.update(s, a, t.reward, s2, t.done && !t.truncated);
            s = s2;
            ret += t.reward;
            len += 1;
            if t.done || t.truncated {
                break;
            }
        }
        (ret, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::CartPole;
    use crate::wrappers::TimeLimit;

    #[test]
    fn state_indexing_is_injective_within_bins() {
        let agent = QTableAgent::new(4, vec![0.0, 0.0], vec![1.0, 1.0], 2, 0);
        let mut seen = std::collections::HashSet::new();
        for i in 0..4 {
            for j in 0..4 {
                let obs = [i as f32 * 0.25 + 0.1, j as f32 * 0.25 + 0.1];
                assert!(seen.insert(agent.state_of(&obs)));
            }
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn out_of_bounds_clamped() {
        let agent = QTableAgent::new(4, vec![0.0], vec![1.0], 2, 0);
        assert_eq!(agent.state_of(&[-5.0]), 0);
        assert_eq!(agent.state_of(&[5.0]), 3);
    }

    #[test]
    fn update_moves_toward_target() {
        let mut agent = QTableAgent::new(2, vec![0.0], vec![1.0], 2, 0);
        agent.alpha = 0.5;
        agent.update(0, 1, 1.0, 1, true);
        let q = agent.row(0)[1];
        assert!((q - 0.5).abs() < 1e-6);
        agent.update(0, 1, 1.0, 1, true);
        assert!((agent.row(0)[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn learns_cartpole_above_random() {
        // Coarse 6-bin discretisation learns to hold the pole noticeably
        // longer than random within a few thousand episodes.
        let mut env = TimeLimit::new(CartPole::new(), 200);
        env.seed(0);
        let mut agent = QTableAgent::new(
            6,
            vec![-2.4, -3.0, -0.21, -3.0],
            vec![2.4, 3.0, 0.21, 3.0],
            2,
            0,
        );
        agent.epsilon = 0.15;
        agent.alpha = 0.15;
        let mut first100 = 0.0;
        let mut last100 = 0.0;
        let episodes = 3000;
        for ep in 0..episodes {
            let (ret, _) = agent.train_episode(&mut env, 200);
            if ep < 100 {
                first100 += ret;
            }
            if ep >= episodes - 100 {
                last100 += ret;
            }
        }
        assert!(
            last100 > first100 * 2.0,
            "no learning: first {first100}, last {last100}"
        );
    }
}
