//! Experience replay buffer (Mnih et al. 2015, Table-I capacity 50 000).
//!
//! Flat ring storage in struct-of-arrays layout so sampling a batch is a
//! gather straight into the artifact's operand layout — no per-transition
//! allocation.

use crate::core::rng::Pcg32;
use crate::runtime::dqn_exec::Batch;

/// Fixed-capacity transition store with uniform sampling.
pub struct ReplayBuffer {
    capacity: usize,
    obs_dim: usize,
    s: Vec<f32>,
    a: Vec<i32>,
    r: Vec<f32>,
    s2: Vec<f32>,
    done: Vec<f32>,
    head: usize,
    len: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize, obs_dim: usize) -> ReplayBuffer {
        ReplayBuffer {
            capacity,
            obs_dim,
            s: vec![0.0; capacity * obs_dim],
            a: vec![0; capacity],
            r: vec![0.0; capacity],
            s2: vec![0.0; capacity * obs_dim],
            done: vec![0.0; capacity],
            head: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Store one transition (overwrites the oldest when full).
    ///
    /// `done` must reflect *termination*, not truncation: a truncated
    /// episode's final transition bootstraps normally (the TimeLimit
    /// wrapper keeps the two separate precisely for this).
    pub fn push(&mut self, s: &[f32], a: usize, r: f32, s2: &[f32], done: bool) {
        debug_assert_eq!(s.len(), self.obs_dim);
        debug_assert_eq!(s2.len(), self.obs_dim);
        let i = self.head;
        self.s[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(s);
        self.a[i] = a as i32;
        self.r[i] = r;
        self.s2[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(s2);
        self.done[i] = done as u8 as f32;
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Sample `batch.a.len()`-sized batch uniformly into `batch`
    /// (resizing it to `n`).  Requires `len() >= n`.
    pub fn sample_into(&self, rng: &mut Pcg32, n: usize, batch: &mut Batch) {
        assert!(self.len >= n, "buffer has {} < {n} transitions", self.len);
        batch.s.resize(n * self.obs_dim, 0.0);
        batch.a.resize(n, 0);
        batch.r.resize(n, 0.0);
        batch.s2.resize(n * self.obs_dim, 0.0);
        batch.done.resize(n, 0.0);
        for k in 0..n {
            let i = rng.below(self.len as u32) as usize;
            batch.s[k * self.obs_dim..(k + 1) * self.obs_dim]
                .copy_from_slice(&self.s[i * self.obs_dim..(i + 1) * self.obs_dim]);
            batch.a[k] = self.a[i];
            batch.r[k] = self.r[i];
            batch.s2[k * self.obs_dim..(k + 1) * self.obs_dim]
                .copy_from_slice(&self.s2[i * self.obs_dim..(i + 1) * self.obs_dim]);
            batch.done[k] = self.done[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_wraps() {
        let mut rb = ReplayBuffer::new(3, 2);
        assert!(rb.is_empty());
        for i in 0..5 {
            let v = i as f32;
            rb.push(&[v, v], i, v, &[v + 1.0, v + 1.0], false);
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.capacity(), 3);
        // Oldest two (0, 1) overwritten; remaining actions are {2, 3, 4}.
        let mut rng = Pcg32::new(0, 1);
        let mut batch = Batch::default();
        rb.sample_into(&mut rng, 3, &mut batch);
        assert!(batch.a.iter().all(|&a| (2..=4).contains(&a)));
    }

    #[test]
    fn sample_layout_is_consistent() {
        let mut rb = ReplayBuffer::new(10, 2);
        for i in 0..10 {
            let v = i as f32;
            rb.push(&[v, -v], i, v * 10.0, &[v + 0.5, -v - 0.5], i % 2 == 0);
        }
        let mut rng = Pcg32::new(3, 3);
        let mut batch = Batch::default();
        rb.sample_into(&mut rng, 6, &mut batch);
        for k in 0..6 {
            let a = batch.a[k] as f32;
            assert_eq!(batch.s[k * 2], a);
            assert_eq!(batch.s[k * 2 + 1], -a);
            assert_eq!(batch.r[k], a * 10.0);
            assert_eq!(batch.s2[k * 2], a + 0.5);
            assert_eq!(batch.done[k], (batch.a[k] % 2 == 0) as u8 as f32);
        }
    }

    #[test]
    #[should_panic]
    fn sampling_more_than_stored_panics() {
        let rb = ReplayBuffer::new(10, 1);
        let mut rng = Pcg32::new(0, 1);
        let mut batch = Batch::default();
        rb.sample_into(&mut rng, 1, &mut batch);
    }

    #[test]
    fn sampling_covers_the_buffer() {
        let mut rb = ReplayBuffer::new(8, 1);
        for i in 0..8 {
            rb.push(&[i as f32], i, 0.0, &[0.0], false);
        }
        let mut rng = Pcg32::new(1, 1);
        let mut batch = Batch::default();
        let mut seen = [false; 8];
        for _ in 0..50 {
            rb.sample_into(&mut rng, 4, &mut batch);
            for &a in &batch.a {
                seen[a as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }
}
