//! Energy/carbon report — the Table-II row format.

use std::fmt;

/// One measured region's energy accounting.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub label: String,
    /// Process CPU time charged (seconds).
    pub cpu_seconds: f64,
    /// Wall-clock duration of the region (seconds).
    pub wall_seconds: f64,
    /// Busy fraction in [0, 1].
    pub utilisation: f64,
    /// Estimated energy in kWh.
    pub kwh: f64,
    /// Estimated emissions in kg CO2.
    pub co2_kg: f64,
    /// Model constants, recorded for reproducibility.
    pub tdp_watts: f64,
    pub carbon_intensity: f64,
}

impl EnergyReport {
    /// Energy in milliwatt-hours — the unit Table II reports.
    pub fn mwh(&self) -> f64 {
        self.kwh * 1e6
    }

    /// Ratio of another report's emissions to this one's (Table II's
    /// "Ratio" column with `self` as CaiRL and `other` as Gym).
    pub fn co2_ratio_vs(&self, other: &EnergyReport) -> f64 {
        if self.co2_kg <= 0.0 {
            return f64::INFINITY;
        }
        other.co2_kg / self.co2_kg
    }

    /// One Table-II-style CSV row: label, cpu_s, wall_s, kwh, mwh, co2.
    pub fn csv_row(&self) -> String {
        format!(
            "{},{:.3},{:.3},{:.9},{:.6},{:.9}",
            self.label, self.cpu_seconds, self.wall_seconds, self.kwh,
            self.mwh(), self.co2_kg
        )
    }
}

impl fmt::Display for EnergyReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] cpu={:.2}s wall={:.2}s util={:.0}% energy={:.6} mWh co2={:.3e} kg \
             (TDP {:.0} W, {:.3} kg/kWh)",
            self.label,
            self.cpu_seconds,
            self.wall_seconds,
            self.utilisation * 100.0,
            self.mwh(),
            self.co2_kg,
            self.tdp_watts,
            self.carbon_intensity,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(co2: f64) -> EnergyReport {
        EnergyReport {
            label: "test".into(),
            cpu_seconds: 1.0,
            wall_seconds: 1.0,
            utilisation: 1.0,
            kwh: co2 / 0.475,
            co2_kg: co2,
            tdp_watts: 95.0,
            carbon_intensity: 0.475,
        }
    }

    #[test]
    fn mwh_conversion() {
        let r = report(0.475); // 1 kWh
        assert!((r.mwh() - 1e6).abs() < 1.0);
    }

    #[test]
    fn ratio_matches_table_semantics() {
        let cairl = report(0.000014);
        let gym = report(0.000067);
        let ratio = cairl.co2_ratio_vs(&gym);
        assert!((ratio - 4.785).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn display_and_csv_contain_label() {
        let r = report(1.0);
        assert!(r.to_string().contains("[test]"));
        assert!(r.csv_row().starts_with("test,"));
    }
}
