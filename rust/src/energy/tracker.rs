//! Process energy tracking via `/proc/self/stat` CPU-time sampling.
//!
//! The drop-in usage mirrors the experiment-impact-tracker: wrap the
//! measured region in `start()` / `stop()`, get an [`EnergyReport`].
//! CPU time (utime + stime) rather than wall time is the integration
//! variable so that sleeping code is not charged — exactly the property
//! that makes the Table-II graphical comparison meaningful (the blocked
//! GL readback *burns* CPU, sleeping does not).

use std::time::Instant;

use crate::energy::power_model::PowerModel;
use crate::energy::report::EnergyReport;

/// Read this process's cumulative CPU seconds.
///
/// Primary source: `/proc/thread-self/schedstat` (nanosecond-resolution
/// scheduler accounting for the *calling thread* — the 10 ms USER_HZ
/// ticks of `/proc/self/stat` are too coarse for CaiRL-side workloads
/// that finish in milliseconds, and tests/benches run their workload on
/// the thread that holds the tracker).  Falls back to process `stat`
/// ticks if schedstat is unavailable.  Multi-threaded regions should be
/// tracked from the thread doing the work.
pub fn process_cpu_seconds() -> f64 {
    if let Ok(sched) = std::fs::read_to_string("/proc/thread-self/schedstat") {
        if let Some(ns) = sched
            .split_whitespace()
            .next()
            .and_then(|s| s.parse::<f64>().ok())
        {
            return ns / 1e9;
        }
    }
    let Ok(stat) = std::fs::read_to_string("/proc/self/stat") else {
        return 0.0;
    };
    // Fields after the parenthesised comm: utime is field 14, stime 15
    // (1-based, counting from pid).  comm may contain spaces, so split
    // after the closing paren.
    let Some(rest) = stat.rsplit(is_close_paren).next() else {
        return 0.0;
    };
    let fields: Vec<&str> = rest.split_whitespace().collect();
    // rest starts at field 3 ("state"), so utime/stime are at 11/12.
    let utime: f64 = fields.get(11).and_then(|s| s.parse().ok()).unwrap_or(0.0);
    let stime: f64 = fields.get(12).and_then(|s| s.parse().ok()).unwrap_or(0.0);
    let hz = 100.0; // USER_HZ on linux
    (utime + stime) / hz
}

fn is_close_paren(c: char) -> bool {
    c == ')'
}

/// A start/stop energy measurement over the current process.
pub struct EnergyTracker {
    model: PowerModel,
    start_cpu: f64,
    start_wall: Instant,
    label: String,
}

impl EnergyTracker {
    /// Begin measuring now.
    pub fn start(label: &str, model: PowerModel) -> EnergyTracker {
        EnergyTracker {
            model,
            start_cpu: process_cpu_seconds(),
            start_wall: Instant::now(),
            label: label.to_string(),
        }
    }

    /// With the default (8700K-calibrated) power model.
    pub fn start_default(label: &str) -> EnergyTracker {
        Self::start(label, PowerModel::default())
    }

    /// End the measurement and produce a report.
    pub fn stop(self) -> EnergyReport {
        let cpu_seconds = (process_cpu_seconds() - self.start_cpu).max(0.0);
        let wall_seconds = self.start_wall.elapsed().as_secs_f64();
        // Utilisation: busy fraction of one core over the wall interval,
        // capped at 1 (multi-core bursts count as full utilisation).
        let utilisation = if wall_seconds > 0.0 {
            (cpu_seconds / wall_seconds).min(1.0)
        } else {
            0.0
        };
        let kwh = self.model.energy_kwh(cpu_seconds, utilisation);
        EnergyReport {
            label: self.label,
            cpu_seconds,
            wall_seconds,
            utilisation,
            kwh,
            co2_kg: self.model.co2_kg(kwh),
            tdp_watts: self.model.tdp_watts,
            carbon_intensity: self.model.carbon_intensity_kg_per_kwh,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_seconds_monotonic_under_load() {
        let a = process_cpu_seconds();
        // Burn ~30 ms of CPU.
        let t0 = Instant::now();
        let mut x = 0u64;
        while t0.elapsed().as_millis() < 30 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(x);
        let b = process_cpu_seconds();
        assert!(b >= a, "{b} < {a}");
    }

    #[test]
    fn tracker_charges_busy_work() {
        let tracker = EnergyTracker::start_default("busy");
        let t0 = Instant::now();
        let mut x = 1u64;
        while t0.elapsed().as_millis() < 120 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(x);
        let report = tracker.stop();
        assert!(report.cpu_seconds > 0.05, "{report:?}");
        assert!(report.kwh > 0.0);
        assert!(report.co2_kg > 0.0);
        assert!(report.utilisation > 0.5);
    }

    #[test]
    fn tracker_does_not_charge_sleep() {
        let tracker = EnergyTracker::start_default("sleepy");
        std::thread::sleep(std::time::Duration::from_millis(120));
        let report = tracker.stop();
        assert!(
            report.cpu_seconds < 0.06,
            "sleep charged {} cpu-s",
            report.cpu_seconds
        );
        assert!(report.wall_seconds >= 0.11);
    }

    #[test]
    fn report_scales_with_work() {
        let burn = |ms: u64| {
            let t = EnergyTracker::start_default("scale");
            let t0 = Instant::now();
            let mut x = 1u64;
            while t0.elapsed().as_millis() < ms as u128 {
                x = x.wrapping_mul(0x5851f42d4c957f2d).wrapping_add(1);
            }
            std::hint::black_box(x);
            t.stop().kwh
        };
        let small = burn(50);
        let large = burn(250);
        assert!(large > small * 2.0, "small={small} large={large}");
    }
}
