//! Energy & carbon accounting — the experiment-impact-tracker surrogate
//! behind Table II (§V-C).
//!
//! Henderson et al.'s tracker estimates `energy = power x time` from
//! hardware counters and converts to CO2 via a grid carbon-intensity
//! factor.  RAPL/nvidia-smi are not available in this image, so the
//! [`tracker::EnergyTracker`] samples *process CPU time* from
//! `/proc/self/stat` and applies a TDP-based power model
//! ([`power_model`]): the methodology (and therefore every *ratio* the
//! paper reports) is preserved; absolute joules scale with the assumed
//! TDP constant, which is documented in the report itself.

pub mod power_model;
pub mod report;
pub mod tracker;

pub use power_model::PowerModel;
pub use report::EnergyReport;
pub use tracker::EnergyTracker;
