//! CPU power model: TDP-proportional power draw.
//!
//! `power = idle + (tdp - idle) * utilisation`, the standard first-order
//! model the experiment-impact-tracker falls back to when RAPL is
//! unavailable.  Defaults model the paper's testbed CPU (Intel 8700K,
//! 95 W TDP) so Table-II magnitudes are comparable.

/// Linear utilisation -> watts model.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Package idle draw in watts.
    pub idle_watts: f64,
    /// Package full-load draw in watts (TDP).
    pub tdp_watts: f64,
    /// Grid carbon intensity in kg CO2 per kWh (world average ~0.475,
    /// the tracker's default).
    pub carbon_intensity_kg_per_kwh: f64,
    /// Power-usage-effectiveness multiplier (datacentre overhead; 1.0
    /// for a workstation like the paper's).
    pub pue: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            idle_watts: 10.0,
            tdp_watts: 95.0, // Intel 8700K, the paper's testbed
            carbon_intensity_kg_per_kwh: 0.475,
            pue: 1.0,
        }
    }
}

impl PowerModel {
    /// Average watts at a given utilisation in `[0, 1]`.
    pub fn watts(&self, utilisation: f64) -> f64 {
        let u = utilisation.clamp(0.0, 1.0);
        (self.idle_watts + (self.tdp_watts - self.idle_watts) * u) * self.pue
    }

    /// Energy in kWh for `cpu_seconds` of single-core busy time.
    ///
    /// Utilisation is attributed per-core-second (the tracker's
    /// convention): one core fully busy for `s` seconds draws
    /// `watts(1.0) / n_cores * s` beyond idle amortisation.  We use the
    /// simpler whole-package attribution over busy time, matching how
    /// the tracker reports single-process experiments.
    pub fn energy_kwh(&self, cpu_seconds: f64, utilisation: f64) -> f64 {
        self.watts(utilisation) * cpu_seconds / 3600.0 / 1000.0
    }

    /// Kilograms of CO2 for an energy amount.
    pub fn co2_kg(&self, kwh: f64) -> f64 {
        kwh * self.carbon_intensity_kg_per_kwh
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watts_interpolates_idle_to_tdp() {
        let m = PowerModel::default();
        assert_eq!(m.watts(0.0), 10.0);
        assert_eq!(m.watts(1.0), 95.0);
        assert!((m.watts(0.5) - 52.5).abs() < 1e-9);
        // Clamped outside [0, 1].
        assert_eq!(m.watts(2.0), 95.0);
        assert_eq!(m.watts(-1.0), 10.0);
    }

    #[test]
    fn energy_scales_linearly_with_time() {
        let m = PowerModel::default();
        let one_hour = m.energy_kwh(3600.0, 1.0);
        assert!((one_hour - 0.095).abs() < 1e-9);
        assert!((m.energy_kwh(7200.0, 1.0) - 2.0 * one_hour).abs() < 1e-12);
    }

    #[test]
    fn co2_uses_intensity() {
        let m = PowerModel::default();
        assert!((m.co2_kg(1.0) - 0.475).abs() < 1e-12);
    }

    #[test]
    fn pue_multiplies_power() {
        let m = PowerModel {
            pue: 1.5,
            ..Default::default()
        };
        assert!((m.watts(1.0) - 142.5).abs() < 1e-9);
    }
}
