//! # CaiRL — a high-performance reinforcement-learning environment toolkit
//!
//! Reproduction of *"CaiRL: A High-Performance Reinforcement Learning
//! Environment Toolkit"* (Andersen, Goodwin, Granmo — IEEE CoG 2022) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the toolkit itself: native environments,
//!   wrappers, spaces, runners (interpreted-script and bytecode-VM
//!   surrogates for the paper's Python/Flash runtimes), a software
//!   renderer, agents, energy accounting, tournaments, and the experiment
//!   coordinator.  Rust replaces the paper's C++; the paper's compile-time
//!   template composition maps onto Rust generics/monomorphisation.
//! * **L2 (python/compile/model.py)** — the DQN compute graph (Table I),
//!   AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — fused Pallas kernels (Q-network
//!   forward/backward, batched CartPole physics, batched software
//!   rasteriser), lowered inside the L2 artifacts.
//!
//! Python never runs after `make artifacts`: [`runtime`] loads the HLO
//! artifacts through the PJRT C API (`xla` crate) and the whole training /
//! benchmarking hot path is Rust.
//!
//! ## The executor layer
//!
//! Batched environment execution goes through one interface,
//! [`coordinator::pool::BatchedExecutor`], with three interchangeable
//! implementations: sequential [`coordinator::vec_env::VecEnv`] (the
//! bit-exact reference), [`coordinator::pool::EnvPool`]
//! (persistent-worker threads, barrier per batch, trajectories identical
//! to `VecEnv` for any thread count) and
//! [`coordinator::pool::AsyncEnvPool`] (workers run ahead over a
//! ready-queue, EnvPool-style `send_actions`/`recv_batch` with zero-copy
//! per-lane slots — steady state allocates nothing).  Workloads
//! select an executor via [`coordinator::config::ExecutorSettings`] or
//! `cairl run --executor pool --lanes 1024`; see README §"Choosing an
//! executor".
//!
//! Pools may be **scenario mixtures** — per-lane env ids behind the same
//! interface (`cairl run --env "CartPole-v1:32,Acrobot-v1:16"`), with
//! observations padded to the widest lane and
//! [`coordinator::pool::BatchedExecutor::lane_specs`] describing the
//! per-lane layout; see README §"Scenario mixtures".
//!
//! Inside every executor, contiguous same-spec lanes form **groups**
//! stepped through one [`core::batch::BatchEnv`] call: the
//! classic-control envs ship fused SoA kernels (state in parallel
//! `Vec<f32>` columns, registered `TimeLimit` — and a single trailing
//! `NormalizeObs`/`RewardScale`, folded in as a per-lane affine
//! epilogue — bit-identical to scalar stepping), everything else runs
//! on the [`core::batch::ScalarBatch`] fallback.  `cairl run --kernel
//! scalar|fused` flips the mode for A/B benching; see README §"Batch
//! kernels".
//!
//! Executors also scale **out of process**: `cairl serve` hosts any
//! executor configuration behind a Unix-socket/TCP listener
//! ([`shard::ShardServer`]) and [`shard::ShardedEnvPool`] is a
//! `BatchedExecutor` over one or more such shards — same `lane_specs()`
//! layout, bit-identical trajectories, with mixture components placed
//! by measured per-env step cost ([`shard::ShardPlan`]).  The fabric is
//! production-shaped: requests are sequence-numbered and pipelined
//! (`cairl run --shard ... --pipeline 4` keeps four batches in flight
//! per shard), a lost connection fails over transparently (re-dial with
//! bounded backoff, deterministic replay of the lost lanes, re-plan
//! onto a surviving shard as the fallback — trajectories stay
//! bit-identical throughout), and one daemon serves many clients under
//! an optional lane budget and auth token (`cairl serve --max-lanes
//! --token`, introspected live via `cairl serve --status ADDR`).
//! `cairl run --shard unix:///tmp/s0.sock` flips a workload from local
//! to remote; see README §"Sharded execution", the layer map in
//! `docs/ARCHITECTURE.md` and the normative wire spec in
//! `docs/shard-protocol.md`.
//!
//! Every executor, the shard client and the serve daemon record into
//! the zero-allocation [`telemetry`] metrics registry (JSON snapshot in
//! `serve --status`, Prometheus text via `cairl metrics` / `cairl run
//! --metrics FILE`), and any batched workload can be captured as a
//! deterministic, checksummed trajectory tape (`cairl run --record
//! FILE`) and re-executed bit-for-bit on a fresh executor of any kind
//! (`cairl replay --tape FILE`); see README §"Observability".
//!
//! ## The registry: `EnvSpec`, kwargs, wrapper chains
//!
//! Environment construction is spec-driven
//! ([`coordinator::registry::EnvSpec`]): a runtime `RwLock` registry
//! maps ids to specs carrying typed kwarg defaults, a declarative
//! [`wrappers::WrapperSpec`] chain and a builder.  [`make`] accepts
//! Gym-style id kwargs (`"CartPole-v1?max_steps=200"`),
//! [`make_with`] takes explicit [`core::kwargs::Kwargs`], and
//! [`register`] / [`register_script`] extend the namespace at runtime —
//! `cairl run --register-script MyEnv=my.mpy --env "Script/MyEnv:8"`
//! runs a user MiniScript env in a mixture pool without recompiling.
//! See README §"Registry & EnvSpec".
//!
//! ## Quickstart
//!
//! ```no_run
//! use cairl::prelude::*;
//!
//! // Gym-compatible dynamic API (paper Listing 2):
//! let mut env = cairl::make("CartPole-v1").unwrap();
//! let obs = env.reset();
//! let mut rng = Pcg32::new(0, 1);
//! for _ in 0..200 {
//!     let a = env.action_space().sample(&mut rng);
//!     let step = env.step(&a);
//!     if step.done { break; }
//! }
//! # let _ = obs;
//!
//! // Parameterized construction (Gym-style id kwargs):
//! let env = cairl::make("CartPole-v1?max_steps=200").unwrap();
//! # let _ = env;
//!
//! // Declarative wrapper chains (the --wrap / config grammar):
//! let chain = WrapperSpec::parse_chain("TimeLimit(200),NormalizeObs").unwrap();
//! let env = apply_wrappers(Box::new(CartPole::new()), &chain);
//! # let _ = env;
//!
//! // Zero-cost static composition (paper Listing 1):
//! let env = Flatten::new(TimeLimit::new(CartPole::new(), 200));
//! # let _ = env;
//! ```

// Style lints this codebase consciously opts out of: environments expose
// `new()` constructors without `Default` (Gym idiom), physics constants
// keep their published precision, and index-heavy kernel/raster math
// reads better as ranges.
#![allow(clippy::new_without_default)]
#![allow(clippy::excessive_precision)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_range_contains)]
#![allow(clippy::too_many_arguments)]

pub mod agents;
pub mod coordinator;
pub mod core;
pub mod energy;
pub mod envs;
pub mod faults;
pub mod flash;
pub mod puzzles;
pub mod render;
pub mod runtime;
pub mod script;
pub mod shard;
pub mod telemetry;
pub mod tooling;
pub mod wrappers;

pub use crate::coordinator::registry::{
    list_envs, make, make_with, register, register_script, EnvSpec,
};
pub use crate::core::env::{DynEnv, Env, Step};
pub use crate::core::spaces::{Action, Space};

/// Everything a typical experiment needs.
pub mod prelude {
    pub use crate::coordinator::experiment::{ExecutorKind, KernelMode};
    pub use crate::coordinator::pool::{
        AsyncEnvPool, BatchedExecutor, EnvPool, LaneGroupSpec, LaneSpec, PanicPolicy,
    };
    pub use crate::coordinator::registry::{
        list_envs, make, make_with, register, register_script, EnvSpec, MixtureEntry,
        MixtureSpec,
    };
    pub use crate::coordinator::vec_env::VecEnv;
    pub use crate::core::batch::{BatchEnv, DynBatchEnv, FusedBatch, LaneKernel, ScalarBatch};
    pub use crate::core::env::{DynEnv, Env, Step};
    pub use crate::core::kwargs::{Kwargs, KwargValue};
    pub use crate::core::rng::Pcg32;
    pub use crate::core::spaces::{Action, Space};
    pub use crate::envs::{Acrobot, CartPole, MountainCar, Pendulum};
    pub use crate::faults::{ChaosProfile, FaultPlan, FaultyEnv};
    pub use crate::render::Framebuffer;
    pub use crate::shard::{
        ServeConfig, ShardPlan, ShardPoolOptions, ShardServer, ShardedEnvPool,
    };
    pub use crate::telemetry::{TapeHeader, TapeReader, TapeWriter};
    pub use crate::wrappers::{
        apply_wrappers, Flatten, RecordEpisodeStatistics, TimeLimit, WrapperSpec,
    };
}
