"""L1 Pallas kernel: batched software rasteriser for the CartPole scene.

The paper's central rendering claim (§II-B, Fig. 1): for simple 2D scenes a
*software* renderer that keeps the framebuffer in fast memory beats hardware
rendering because the GPU->CPU readback stall dominates.  TPU translation
(DESIGN.md §Hardware-Adaptation): the framebuffer tile lives in VMEM for the
whole compose loop — a 64x64 f32 buffer is 16 KB, far under the VMEM budget
— and is written to HBM exactly once.  Geometry is expressed as coordinate
grids + masks (`broadcasted_iota` + `where`), the vectorised equivalent of
the paper's SIMD scanline fills.

Scene (matches rust/src/render/software.rs::paint_cartpole so golden-pixel
tests can cross-check the two implementations):
  - background 0.0
  - track:  horizontal line, 1 px, intensity 0.3, at y = CART_Y + CART_H/2
  - cart:   CART_W x CART_H rectangle, intensity 0.6, centred at world x
  - pole:   line of POLE_LEN px from the cart centre at angle theta,
            thickness ~2 px, intensity 1.0

interpret=True: CPU-PJRT execution path (see fused_mlp.py docstring).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

H = 64
W = 64
X_THRESHOLD = 2.4  # world |x| mapped to the framebuffer width
CART_W = 8
CART_H = 4
CART_Y = 48  # cart centre row
POLE_LEN = 20.0  # pixels
POLE_HALF_THICK = 1.0

TRACK_I = 0.3
CART_I = 0.6
POLE_I = 1.0


def _scene(x_world, theta):
    """Render one (x, theta) pair into an f32[H, W] intensity buffer."""
    rows = jax.lax.broadcasted_iota(jnp.float32, (H, W), 0)
    cols = jax.lax.broadcasted_iota(jnp.float32, (H, W), 1)

    cx = (x_world / X_THRESHOLD) * (W / 2 - CART_W) + W / 2
    cy = jnp.float32(CART_Y)

    frame = jnp.zeros((H, W), jnp.float32)

    # Track line (drawn first; cart and pole paint over it).
    track = (rows == jnp.float32(CART_Y + CART_H // 2))
    frame = jnp.where(track, TRACK_I, frame)

    # Cart rectangle.
    cart = (
        (jnp.abs(cols - cx) <= CART_W / 2)
        & (jnp.abs(rows - cy) <= CART_H / 2)
    )
    frame = jnp.where(cart, CART_I, frame)

    # Pole: distance from each pixel to the segment
    # P(t) = C + t * d, t in [0, POLE_LEN], d = (sin(theta), -cos(theta))
    # (theta = 0 is straight up; screen y grows downward).
    dx = jnp.sin(theta)
    dy = -jnp.cos(theta)
    px = cols - cx
    py = rows - cy
    t = jnp.clip(px * dx + py * dy, 0.0, POLE_LEN)
    dist2 = (px - t * dx) ** 2 + (py - t * dy) ** 2
    pole = dist2 <= POLE_HALF_THICK**2
    frame = jnp.where(pole, POLE_I, frame)
    return frame


def _render_kernel(state_ref, frame_ref):
    s = state_ref[...]
    # vmap over the batch inside the kernel: one VMEM-resident buffer per
    # lane group; all compositing happens before the single HBM write.
    frame_ref[...] = jax.vmap(lambda st: _scene(st[0], st[2]))(s)


def render_cartpole(state):
    """Rasterise B CartPole states into B framebuffers.

    Args:
      state: f32[B, 4] (x, x_dot, theta, theta_dot).

    Returns:
      f32[B, H, W] intensity frames in [0, 1].
    """
    batch = state.shape[0]
    return pl.pallas_call(
        _render_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, H, W), jnp.float32),
        interpret=INTERPRET,
    )(state)
