"""L1 Pallas kernel: batched CartPole-v1 dynamics step.

The paper's §II-B/§III SIMD insight — vectorise the environment's arithmetic
so one instruction advances many lanes — mapped onto the TPU VPU: one kernel
invocation advances B independent CartPole environments.  All branches of
the Gym dynamics (force sign, termination, auto-reset masking) are rewritten
branchless with `jnp.where`, exactly the transformation the paper applies
for CPU SIMD.

State layout f32[B, 4]: (x, x_dot, theta, theta_dot) — identical to Gym's
CartPole-v1 so trajectories can be cross-checked bit-for-bit (modulo f32
rounding) against the L3 rust implementation and the MiniPy scripted
baseline.

interpret=True: CPU-PJRT execution path (see fused_mlp.py docstring).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

# Gym CartPole-v1 constants.
GRAVITY = 9.8
MASS_CART = 1.0
MASS_POLE = 0.1
TOTAL_MASS = MASS_CART + MASS_POLE
LENGTH = 0.5  # half pole length
POLEMASS_LENGTH = MASS_POLE * LENGTH
FORCE_MAG = 10.0
TAU = 0.02  # seconds between state updates (Euler integration)
THETA_THRESHOLD = 12 * 2 * jnp.pi / 360  # ~0.2094 rad
X_THRESHOLD = 2.4


def _step_kernel(state_ref, action_ref, next_ref, reward_ref, done_ref):
    """One Euler step of the CartPole dynamics for every lane."""
    s = state_ref[...]
    x, x_dot, theta, theta_dot = s[:, 0], s[:, 1], s[:, 2], s[:, 3]
    # action is {0, 1} encoded f32; force = +-FORCE_MAG, branchless.
    a = action_ref[...]
    force = jnp.where(a > 0.5, FORCE_MAG, -FORCE_MAG)

    costheta = jnp.cos(theta)
    sintheta = jnp.sin(theta)
    temp = (force + POLEMASS_LENGTH * theta_dot**2 * sintheta) / TOTAL_MASS
    thetaacc = (GRAVITY * sintheta - costheta * temp) / (
        LENGTH * (4.0 / 3.0 - MASS_POLE * costheta**2 / TOTAL_MASS)
    )
    xacc = temp - POLEMASS_LENGTH * thetaacc * costheta / TOTAL_MASS

    # Semi-implicit is NOT what Gym uses; Gym CartPole is explicit Euler
    # ("euler" kinematics_integrator): position first with old velocity.
    x = x + TAU * x_dot
    x_dot = x_dot + TAU * xacc
    theta = theta + TAU * theta_dot
    theta_dot = theta_dot + TAU * thetaacc

    done = (
        (x < -X_THRESHOLD)
        | (x > X_THRESHOLD)
        | (theta < -THETA_THRESHOLD)
        | (theta > THETA_THRESHOLD)
    )
    next_ref[...] = jnp.stack([x, x_dot, theta, theta_dot], axis=1)
    # Gym semantics: reward 1.0 on every step including the terminating one.
    reward_ref[...] = jnp.ones_like(x)
    done_ref[...] = done.astype(jnp.float32)


def env_step_cartpole(state, action):
    """Advance B CartPole environments one step.

    Args:
      state: f32[B, 4] current states.
      action: f32[B] actions in {0.0, 1.0}.

    Returns:
      (next_state f32[B,4], reward f32[B], done f32[B]).
    """
    batch = state.shape[0]
    return pl.pallas_call(
        _step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((batch, 4), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.float32),
        ),
        interpret=INTERPRET,
    )(state, action)
