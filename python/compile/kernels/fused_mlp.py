"""L1 Pallas kernel: fused 3-layer MLP Q-network forward and backward.

The paper's DQN (Table I: units 32,32, elu) evaluated as a single fused
kernel: all weights are VMEM-resident, the observation batch is read from
HBM once, intermediates (h1, h2) never leave VMEM, and the Q-value batch is
written once.  This is the TPU translation of the paper's "keep the hot data
in fast memory" software-rendering/SIMD insight (DESIGN.md
§Hardware-Adaptation).

Backward is a second fused kernel that rematerialises h1/h2 in VMEM (cheap
for 32-wide layers) instead of spilling activations to HBM, then produces
all six parameter gradients in one pass.  Both are wired together with
`jax.custom_vjp` so `dqn_train` can differentiate straight through the
kernel.

interpret=True everywhere: real-TPU lowering emits Mosaic custom-calls the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-PJRT execution path; see module docstring.


def _elu(x):
    # elu(x) = x if x > 0 else exp(x) - 1   (alpha = 1, Table I activation)
    return jnp.where(x > 0, x, jnp.exp(jnp.minimum(x, 0.0)) - 1.0)


def _elu_grad(x):
    # d/dx elu(x) = 1 if x > 0 else exp(x)
    return jnp.where(x > 0, 1.0, jnp.exp(jnp.minimum(x, 0.0)))


def _fwd_kernel(obs_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, q_ref):
    """Fused forward: q = (elu(elu(obs@w1+b1)@w2+b2))@w3+b3.

    Single grid point: the whole (B, S) obs block and all weights fit VMEM
    (see DESIGN.md VMEM budget table), so no HBM traffic between layers.
    """
    obs = obs_ref[...]
    h1 = _elu(obs @ w1_ref[...] + b1_ref[...])
    h2 = _elu(h1 @ w2_ref[...] + b2_ref[...])
    q_ref[...] = h2 @ w3_ref[...] + b3_ref[...]


def _bwd_kernel(
    obs_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, dq_ref,
    dw1_ref, db1_ref, dw2_ref, db2_ref, dw3_ref, db3_ref,
):
    """Fused backward: rematerialise activations in VMEM, emit all grads.

    Rematerialisation (recompute h1/h2 from obs) is strictly cheaper than a
    round-trip of the activations through HBM at these layer widths: two
    extra 32-wide matmuls vs 2*B*32 floats of HBM traffic.
    """
    obs = obs_ref[...]
    z1 = obs @ w1_ref[...] + b1_ref[...]
    h1 = _elu(z1)
    z2 = h1 @ w2_ref[...] + b2_ref[...]
    h2 = _elu(z2)
    dq = dq_ref[...]

    # layer 3
    dw3_ref[...] = h2.T @ dq
    db3_ref[...] = jnp.sum(dq, axis=0)
    dh2 = dq @ w3_ref[...].T
    # layer 2
    dz2 = dh2 * _elu_grad(z2)
    dw2_ref[...] = h1.T @ dz2
    db2_ref[...] = jnp.sum(dz2, axis=0)
    dh1 = dz2 @ w2_ref[...].T
    # layer 1
    dz1 = dh1 * _elu_grad(z1)
    dw1_ref[...] = obs.T @ dz1
    db1_ref[...] = jnp.sum(dz1, axis=0)


def _fwd_call(obs, w1, b1, w2, b2, w3, b3):
    batch = obs.shape[0]
    n_act = w3.shape[1]
    return pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, n_act), jnp.float32),
        interpret=INTERPRET,
    )(obs, w1, b1, w2, b2, w3, b3)


def _bwd_call(obs, w1, b1, w2, b2, w3, b3, dq):
    shapes = tuple(
        jax.ShapeDtypeStruct(p.shape, jnp.float32)
        for p in (w1, b1, w2, b2, w3, b3)
    )
    return pl.pallas_call(
        _bwd_kernel,
        out_shape=shapes,
        interpret=INTERPRET,
    )(obs, w1, b1, w2, b2, w3, b3, dq)


@jax.custom_vjp
def fused_mlp(obs, w1, b1, w2, b2, w3, b3):
    """Q-network forward through the fused Pallas kernel.

    Args:
      obs: f32[B, S] observation batch.
      w1/b1, w2/b2, w3/b3: layer parameters (S->H, H->H, H->A).

    Returns:
      f32[B, A] Q-values.
    """
    return _fwd_call(obs, w1, b1, w2, b2, w3, b3)


def _vjp_fwd(obs, w1, b1, w2, b2, w3, b3):
    q = _fwd_call(obs, w1, b1, w2, b2, w3, b3)
    return q, (obs, w1, b1, w2, b2, w3, b3)


def _vjp_bwd(res, dq):
    obs, w1, b1, w2, b2, w3, b3 = res
    dw1, db1, dw2, db2, dw3, db3 = _bwd_call(obs, w1, b1, w2, b2, w3, b3, dq)
    # No gradient w.r.t. observations: DQN never differentiates its inputs.
    return (jnp.zeros_like(obs), dw1, db1, dw2, db2, dw3, db3)


fused_mlp.defvjp(_vjp_fwd, _vjp_bwd)


def mlp_apply(params, obs):
    """Convenience wrapper: params dict -> fused kernel call."""
    return fused_mlp(
        obs,
        params["w1"], params["b1"],
        params["w2"], params["b2"],
        params["w3"], params["b3"],
    )
