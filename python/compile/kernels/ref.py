"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: deliberately written in the most
obvious jnp style, no pallas, no fusion tricks.  pytest asserts that each
kernel matches its oracle to f32 tolerance across a hypothesis-driven sweep
of shapes (python/tests/test_kernel.py).
"""

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- fused_mlp


def elu(x):
    return jnp.where(x > 0, x, jnp.exp(jnp.minimum(x, 0.0)) - 1.0)


def mlp_forward_ref(obs, w1, b1, w2, b2, w3, b3):
    """Plain 3-layer MLP with elu, the Table-I Q-network."""
    h1 = elu(obs @ w1 + b1)
    h2 = elu(h1 @ w2 + b2)
    return h2 @ w3 + b3


def mlp_grads_ref(obs, w1, b1, w2, b2, w3, b3, dq):
    """Parameter cotangents via jax autodiff on the reference forward."""

    def scalarised(w1, b1, w2, b2, w3, b3):
        q = mlp_forward_ref(obs, w1, b1, w2, b2, w3, b3)
        return jnp.sum(q * dq)

    return jax.grad(scalarised, argnums=(0, 1, 2, 3, 4, 5))(
        w1, b1, w2, b2, w3, b3
    )


# ----------------------------------------------------------- env_step (ref)

GRAVITY = 9.8
MASS_CART = 1.0
MASS_POLE = 0.1
TOTAL_MASS = MASS_CART + MASS_POLE
LENGTH = 0.5
POLEMASS_LENGTH = MASS_POLE * LENGTH
FORCE_MAG = 10.0
TAU = 0.02
THETA_THRESHOLD = 12 * 2 * jnp.pi / 360
X_THRESHOLD = 2.4


def cartpole_step_ref(state, action):
    """Single-env Gym CartPole-v1 Euler step, vmapped by the caller."""
    x, x_dot, theta, theta_dot = state
    force = jnp.where(action > 0.5, FORCE_MAG, -FORCE_MAG)
    costheta = jnp.cos(theta)
    sintheta = jnp.sin(theta)
    temp = (force + POLEMASS_LENGTH * theta_dot**2 * sintheta) / TOTAL_MASS
    thetaacc = (GRAVITY * sintheta - costheta * temp) / (
        LENGTH * (4.0 / 3.0 - MASS_POLE * costheta**2 / TOTAL_MASS)
    )
    xacc = temp - POLEMASS_LENGTH * thetaacc * costheta / TOTAL_MASS
    x = x + TAU * x_dot
    x_dot = x_dot + TAU * xacc
    theta = theta + TAU * theta_dot
    theta_dot = theta_dot + TAU * thetaacc
    next_state = jnp.stack([x, x_dot, theta, theta_dot])
    done = (
        (x < -X_THRESHOLD)
        | (x > X_THRESHOLD)
        | (theta < -THETA_THRESHOLD)
        | (theta > THETA_THRESHOLD)
    ).astype(jnp.float32)
    return next_state, jnp.float32(1.0), done


def env_step_cartpole_ref(state, action):
    """Batched oracle: vmap of the single-env step."""
    return jax.vmap(cartpole_step_ref)(state, action)


# -------------------------------------------------------------- render ref


def render_cartpole_ref(state):
    """Batched oracle for the scene rasteriser: literal per-pixel
    semantics expressed with meshgrid (no pallas)."""
    from . import render as rk  # share the geometry constants

    def one(st):
        x_world, theta = st[0], st[2]
        rows, cols = jnp.meshgrid(
            jnp.arange(rk.H, dtype=jnp.float32),
            jnp.arange(rk.W, dtype=jnp.float32),
            indexing="ij",
        )
        cx = (x_world / rk.X_THRESHOLD) * (rk.W / 2 - rk.CART_W) + rk.W / 2
        cy = jnp.float32(rk.CART_Y)
        frame = jnp.zeros((rk.H, rk.W), jnp.float32)
        frame = jnp.where(
            rows == jnp.float32(rk.CART_Y + rk.CART_H // 2), rk.TRACK_I, frame
        )
        cart = (jnp.abs(cols - cx) <= rk.CART_W / 2) & (
            jnp.abs(rows - cy) <= rk.CART_H / 2
        )
        frame = jnp.where(cart, rk.CART_I, frame)
        dx, dy = jnp.sin(theta), -jnp.cos(theta)
        px, py = cols - cx, rows - cy
        t = jnp.clip(px * dx + py * dy, 0.0, rk.POLE_LEN)
        dist2 = (px - t * dx) ** 2 + (py - t * dy) ** 2
        frame = jnp.where(dist2 <= rk.POLE_HALF_THICK**2, rk.POLE_I, frame)
        return frame

    return jax.vmap(one)(state)
