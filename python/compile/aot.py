"""AOT entry point: lower every L2 function to HLO text + manifest.json.

Run once by `make artifacts`; the rust coordinator is self-contained
afterwards.  Interchange format is HLO *text*, never `.serialize()` — the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit-instruction-id protos,
while the text parser reassigns ids (see /opt/xla-example/README.md).

Emits, per EnvSpec:  dqn_act_<env>.hlo.txt, dqn_train_<env>.hlo.txt
plus the vectorised-simulation kernels: env_step_cartpole.hlo.txt,
render_cartpole.hlo.txt, and manifest.json describing operand order/shapes
and golden input/output vectors for the rust integration tests.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels.env_step import env_step_cartpole
from .kernels.render import render_cartpole


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)}
        for a in args
    ]


def _write(out_dir, name, text):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    # Idempotence: leave mtime alone when content is unchanged so the
    # Makefile stamp logic never rebuilds spuriously.
    if os.path.exists(path) and open(path).read() == text:
        return path
    with open(path, "w") as f:
        f.write(text)
    return path


def lower_env_artifacts(spec, out_dir, manifest):
    """dqn_act + dqn_train for one EnvSpec."""
    act_args = model.act_example_args(spec, batch=1)
    lowered = jax.jit(model.dqn_act).lower(*act_args)
    _write(out_dir, f"dqn_act_{spec.name}", to_hlo_text(lowered))
    manifest["artifacts"][f"dqn_act_{spec.name}"] = {
        "file": f"dqn_act_{spec.name}.hlo.txt",
        "inputs": _sig(act_args),
        "outputs": [
            {"shape": [1, spec.n_actions], "dtype": "float32"},
        ],
        "input_names": list(model.PARAM_NAMES) + ["obs"],
        "output_names": ["q"],
    }

    train_args = model.train_example_args(spec)
    lowered = jax.jit(model.dqn_train).lower(*train_args)
    _write(out_dir, f"dqn_train_{spec.name}", to_hlo_text(lowered))
    pn = list(model.PARAM_NAMES)
    manifest["artifacts"][f"dqn_train_{spec.name}"] = {
        "file": f"dqn_train_{spec.name}.hlo.txt",
        "inputs": _sig(train_args),
        "outputs": (
            [{"shape": list(sh), "dtype": "float32"}
             for sh in model.param_shapes(spec)] * 3
            + [{"shape": [], "dtype": "float32"},
               {"shape": [], "dtype": "float32"}]
        ),
        "input_names": (
            pn
            + [f"t{n}" for n in pn]
            + [f"m_{n}" for n in pn]
            + [f"v_{n}" for n in pn]
            + ["t", "s", "a", "r", "s2", "done"]
        ),
        "output_names": (
            pn
            + [f"m_{n}" for n in pn]
            + [f"v_{n}" for n in pn]
            + ["t", "loss"]
        ),
    }


def lower_sim_artifacts(out_dir, manifest, batch=256):
    """Vectorised CartPole stepping + rendering (L1 kernels, standalone)."""
    state = jax.ShapeDtypeStruct((batch, 4), jnp.float32)
    action = jax.ShapeDtypeStruct((batch,), jnp.float32)

    def step_fn(s, a):
        return env_step_cartpole(s, a)

    lowered = jax.jit(step_fn).lower(state, action)
    _write(out_dir, "env_step_cartpole", to_hlo_text(lowered))
    manifest["artifacts"]["env_step_cartpole"] = {
        "file": "env_step_cartpole.hlo.txt",
        "inputs": _sig((state, action)),
        "outputs": [
            {"shape": [batch, 4], "dtype": "float32"},
            {"shape": [batch], "dtype": "float32"},
            {"shape": [batch], "dtype": "float32"},
        ],
        "input_names": ["state", "action"],
        "output_names": ["next_state", "reward", "done"],
    }

    rb = 8  # render batch: 8 frames of 64x64 per call
    rstate = jax.ShapeDtypeStruct((rb, 4), jnp.float32)

    def render_fn(s):
        return (render_cartpole(s),)

    lowered = jax.jit(render_fn).lower(rstate)
    _write(out_dir, "render_cartpole", to_hlo_text(lowered))
    manifest["artifacts"]["render_cartpole"] = {
        "file": "render_cartpole.hlo.txt",
        "inputs": _sig((rstate,)),
        "outputs": [{"shape": [rb, 64, 64], "dtype": "float32"}],
        "input_names": ["state"],
        "output_names": ["frames"],
    }


def goldens(manifest):
    """Deterministic input/output vectors for rust-side smoke tests."""
    spec = model.ENV_SPECS[0]  # cartpole
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, spec)
    obs = jnp.array([[0.01, -0.02, 0.03, -0.04]], jnp.float32)
    (q,) = model.dqn_act(*params, obs)

    # One train step on a fixed synthetic batch: record the resulting loss
    # and the first row of w1 so rust can verify the full 30-in/20-out path.
    zeros = tuple(jnp.zeros_like(p) for p in params)
    b = model.BATCH
    key_s, key_a, key_r = jax.random.split(jax.random.PRNGKey(1), 3)
    s = jax.random.uniform(key_s, (b, spec.obs_dim), jnp.float32, -0.05, 0.05)
    a = jax.random.randint(key_a, (b,), 0, spec.n_actions)
    r = jnp.ones((b,), jnp.float32)
    s2 = s + 0.01
    done = jnp.zeros((b,), jnp.float32)
    out = model.dqn_train(
        *params, *params, *zeros, *zeros, jnp.float32(0.0),
        s, a.astype(jnp.int32), r, s2, done,
    )
    loss = out[-1]

    st = jnp.array(
        [[0.0, 0.0, 0.05, 0.0], [1.0, -0.5, -0.1, 0.2]], jnp.float32
    )
    act = jnp.array([1.0, 0.0], jnp.float32)
    ns, rew, dn = env_step_cartpole(st, act)

    frames = render_cartpole(jnp.zeros((8, 4), jnp.float32))

    manifest["goldens"] = {
        "dqn_act_cartpole": {
            "params_w1_row0": np.asarray(params[0][0]).tolist(),
            "obs": np.asarray(obs).ravel().tolist(),
            "q": np.asarray(q).ravel().tolist(),
        },
        "dqn_train_cartpole": {
            "loss": float(loss),
            "new_w1_00": float(out[0][0, 0]),
            "t": float(out[-2]),
        },
        "env_step_cartpole": {
            "state": np.asarray(st).ravel().tolist(),
            "action": np.asarray(act).ravel().tolist(),
            "next_state": np.asarray(ns).ravel().tolist(),
            "reward": np.asarray(rew).ravel().tolist(),
            "done": np.asarray(dn).ravel().tolist(),
        },
        "render_cartpole": {
            "frame0_sum": float(jnp.sum(frames[0])),
            "frame0_max": float(jnp.max(frames[0])),
        },
    }
    # Seed params for reproducible rust-side training: flattened init
    # parameters for cartpole (PRNGKey(0)), so rust does not need jax.
    manifest["init_params"] = {
        "cartpole": {
            n: np.asarray(p).ravel().tolist()
            for n, p in zip(model.PARAM_NAMES, params)
        }
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "hyperparameters": {
            "gamma": model.GAMMA,
            "lr": model.LR,
            "adam_b1": model.ADAM_B1,
            "adam_b2": model.ADAM_B2,
            "adam_eps": model.ADAM_EPS,
            "hidden": model.HIDDEN,
            "batch": model.BATCH,
            "huber_delta": model.HUBER_DELTA,
        },
        "env_specs": {
            s.name: {"obs_dim": s.obs_dim, "n_actions": s.n_actions}
            for s in model.ENV_SPECS
        },
        "artifacts": {},
    }
    for spec in model.ENV_SPECS:
        lower_env_artifacts(spec, args.out_dir, manifest)
        print(f"lowered dqn_{{act,train}}_{spec.name}")
    lower_sim_artifacts(args.out_dir, manifest)
    print("lowered env_step_cartpole, render_cartpole")
    goldens(manifest)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
