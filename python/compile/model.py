"""L2: the paper's DQN compute graph in JAX, calling the L1 fused kernel.

Table I hyperparameters: units (32, 32), elu, Adam(3e-4), Huber loss,
gamma 0.99, batch 32.  Two jitted entry points are AOT-lowered per
environment spec (aot.py):

  dqn_act(w1..b3, obs)                          -> (q,)
  dqn_train(w1..b3, tw1..tb3, m1..m6, v1..v6, t, s, a, r, s2, done)
                                                -> (w1'..b3', m', v', t', loss)

The flat positional signature is deliberate: the rust runtime
(rust/src/runtime/) feeds PJRT literals by operand index, and the manifest
(aot.py) records the exact ordering.  Python never runs after `make
artifacts` — the rust coordinator owns the training loop, replay buffer,
epsilon schedule and target-network sync (a literal copy, no artifact
needed).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.fused_mlp import fused_mlp

GAMMA = 0.99
LR = 3e-4
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
HIDDEN = 32
BATCH = 32
HUBER_DELTA = 1.0


@dataclass(frozen=True)
class EnvSpec:
    """Static shape info for one environment's DQN artifacts."""

    name: str
    obs_dim: int
    n_actions: int


# Every environment the L3 toolkit trains DQN on.  Pendulum's continuous
# torque is discretised into 5 levels by L3 (the paper benchmarks DQN on all
# four classic-control tasks, which requires the same discretisation);
# multitask observes the flash VM's memory vector (32 floats, 4 actions).
ENV_SPECS = (
    EnvSpec("cartpole", 4, 2),
    EnvSpec("mountaincar", 2, 3),
    EnvSpec("acrobot", 6, 3),
    EnvSpec("pendulum", 3, 5),
    EnvSpec("multitask", 32, 4),
)

PARAM_NAMES = ("w1", "b1", "w2", "b2", "w3", "b3")


def param_shapes(spec):
    """Parameter shapes in PARAM_NAMES order."""
    s, a, h = spec.obs_dim, spec.n_actions, HIDDEN
    return ((s, h), (h,), (h, h), (h,), (h, a), (a,))


def init_params(key, spec):
    """He-uniform init matching the rust-side initialiser (runtime/dqn)."""
    params = []
    for shape in param_shapes(spec):
        if len(shape) == 2:
            key, sub = jax.random.split(key)
            bound = jnp.sqrt(6.0 / shape[0])
            params.append(
                jax.random.uniform(sub, shape, jnp.float32, -bound, bound)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def q_values(params, obs):
    """Q(s, .) through the fused Pallas kernel."""
    w1, b1, w2, b2, w3, b3 = params
    return fused_mlp(obs, w1, b1, w2, b2, w3, b3)


def dqn_act(w1, b1, w2, b2, w3, b3, obs):
    """Greedy-evaluation entry point: Q-values for an observation batch."""
    return (q_values((w1, b1, w2, b2, w3, b3), obs),)


def huber(x):
    """Huber loss with delta=1 (Table I)."""
    absx = jnp.abs(x)
    quad = jnp.minimum(absx, HUBER_DELTA)
    return 0.5 * quad**2 + HUBER_DELTA * (absx - quad)


def td_loss(params, target_params, s, a, r, s2, done):
    """Mean Huber TD error: r + gamma * (1-done) * max_a' Qt(s') - Q(s,a)."""
    q = q_values(params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    q_next = q_values(target_params, s2)
    target = r + GAMMA * (1.0 - done) * jax.lax.stop_gradient(
        jnp.max(q_next, axis=1)
    )
    return jnp.mean(huber(q_sa - target))


def adam_update(p, g, m, v, t):
    """One Adam step (bias-corrected), t is the *new* step count."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1**t)
    vhat = v / (1.0 - ADAM_B2**t)
    return p - LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def dqn_train(
    w1, b1, w2, b2, w3, b3,
    tw1, tb1, tw2, tb2, tw3, tb3,
    m1, m2, m3, m4, m5, m6,
    v1, v2, v3, v4, v5, v6,
    t,
    s, a, r, s2, done,
):
    """One fused DQN train step.

    Returns (w1'..b3', m1'..m6', v1'..v6', t', loss) — 20 outputs, the exact
    order recorded in manifest.json.  A single value_and_grad gives one
    forward for the online net (no recomputation, §Perf L2 target).
    """
    params = (w1, b1, w2, b2, w3, b3)
    target_params = (tw1, tb1, tw2, tb2, tw3, tb3)
    loss, grads = jax.value_and_grad(td_loss)(
        params, target_params, s, a, r, s2, done
    )
    ms = (m1, m2, m3, m4, m5, m6)
    vs = (v1, v2, v3, v4, v5, v6)
    t_new = t + 1.0
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, ms, vs):
        p2, m2_, v2_ = adam_update(p, g, m, v, t_new)
        new_p.append(p2)
        new_m.append(m2_)
        new_v.append(v2_)
    return (*new_p, *new_m, *new_v, t_new, loss)


def act_example_args(spec, batch=1):
    """ShapeDtypeStructs for lowering dqn_act."""
    shapes = param_shapes(spec)
    return tuple(jax.ShapeDtypeStruct(sh, jnp.float32) for sh in shapes) + (
        jax.ShapeDtypeStruct((batch, spec.obs_dim), jnp.float32),
    )


def train_example_args(spec, batch=BATCH):
    """ShapeDtypeStructs for lowering dqn_train (30 operands)."""
    shapes = param_shapes(spec)
    f32 = lambda sh: jax.ShapeDtypeStruct(sh, jnp.float32)
    params = tuple(f32(sh) for sh in shapes)
    return (
        params  # online
        + params  # target
        + params  # adam m
        + params  # adam v
        + (f32(()),)  # t
        + (
            f32((batch, spec.obs_dim)),  # s
            jax.ShapeDtypeStruct((batch,), jnp.int32),  # a
            f32((batch,)),  # r
            f32((batch, spec.obs_dim)),  # s2
            f32((batch,)),  # done
        )
    )
