"""L2 correctness: the DQN train step vs a hand-rolled oracle.

The oracle re-implements TD target, Huber loss, and Adam from first
principles (no shared code with model.py except the reference MLP), so a
green run certifies the fused train-step artifact end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SPEC = model.ENV_SPECS[0]  # cartpole


def synth_batch(key, spec, batch=model.BATCH):
    ks, ka, kr, kd = jax.random.split(key, 4)
    s = jax.random.uniform(ks, (batch, spec.obs_dim), jnp.float32, -1, 1)
    a = jax.random.randint(ka, (batch,), 0, spec.n_actions, jnp.int32)
    r = jax.random.uniform(kr, (batch,), jnp.float32, -1, 1)
    s2 = s + 0.05
    done = jax.random.bernoulli(kd, 0.2, (batch,)).astype(jnp.float32)
    return s, a, r, s2, done


def oracle_loss(params, tparams, s, a, r, s2, done):
    """Independent TD-Huber loss via the reference MLP."""
    q = ref.mlp_forward_ref(s, *params)
    qsa = q[jnp.arange(q.shape[0]), a]
    qn = ref.mlp_forward_ref(s2, *tparams)
    target = r + model.GAMMA * (1 - done) * jnp.max(qn, axis=1)
    err = qsa - target
    abs_e = jnp.abs(err)
    quad = jnp.minimum(abs_e, 1.0)
    return jnp.mean(0.5 * quad**2 + (abs_e - quad))


def oracle_adam(p, g, m, v, t):
    m2 = 0.9 * m + 0.1 * g
    v2 = 0.999 * v + 0.001 * g * g
    mh = m2 / (1 - 0.9**t)
    vh = v2 / (1 - 0.999**t)
    return p - model.LR * mh / (jnp.sqrt(vh) + model.ADAM_EPS), m2, v2


def test_loss_matches_oracle():
    key = jax.random.PRNGKey(3)
    params = model.init_params(key, SPEC)
    tparams = model.init_params(jax.random.PRNGKey(4), SPEC)
    batch = synth_batch(jax.random.PRNGKey(5), SPEC)
    got = model.td_loss(params, tparams, *batch)
    want = oracle_loss(params, tparams, *batch)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_train_step_matches_oracle(seed):
    """Full 30-in/20-out step == independent grad + Adam composition."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    params = model.init_params(k1, SPEC)
    tparams = model.init_params(k2, SPEC)
    zeros = tuple(jnp.zeros_like(p) for p in params)
    batch = synth_batch(k3, SPEC)
    t0 = jnp.float32(7.0)

    out = model.dqn_train(*params, *tparams, *zeros, *zeros, t0, *batch)
    new_p, new_m, new_v, t1, loss = (
        out[0:6], out[6:12], out[12:18], out[18], out[19]
    )
    assert float(t1) == 8.0

    want_loss = oracle_loss(params, tparams, *batch)
    np.testing.assert_allclose(loss, want_loss, rtol=1e-5, atol=1e-6)

    grads = jax.grad(
        lambda ps: oracle_loss(ps, tparams, *batch)
    )(params)
    for p, g, np_, nm, nv in zip(params, grads, new_p, new_m, new_v):
        wp, wm, wv = oracle_adam(p, g, jnp.zeros_like(p), jnp.zeros_like(p), 8.0)
        np.testing.assert_allclose(np_, wp, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(nm, wm, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(nv, wv, rtol=1e-4, atol=1e-7)


def test_train_reduces_loss_on_fixed_batch():
    """200 steps on one batch must drive the TD loss down (sanity: the
    optimiser actually optimises through the pallas kernel)."""
    key = jax.random.PRNGKey(11)
    params = model.init_params(key, SPEC)
    tparams = params
    ms = tuple(jnp.zeros_like(p) for p in params)
    vs = tuple(jnp.zeros_like(p) for p in params)
    batch = synth_batch(jax.random.PRNGKey(12), SPEC)
    t = jnp.float32(0.0)
    step = jax.jit(model.dqn_train)
    first = None
    for _ in range(200):
        out = step(*params, *tparams, *ms, *vs, t, *batch)
        params, ms, vs, t, loss = (
            out[0:6], out[6:12], out[12:18], out[18], out[19]
        )
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.5, (first, float(loss))


def test_target_network_is_stop_gradient():
    """Loss gradient w.r.t. target params must be exactly zero."""
    params = model.init_params(jax.random.PRNGKey(1), SPEC)
    tparams = model.init_params(jax.random.PRNGKey(2), SPEC)
    batch = synth_batch(jax.random.PRNGKey(3), SPEC)
    g = jax.grad(lambda tp: model.td_loss(params, tp, *batch))(tparams)
    # max over next-state Q is the only target-params path and it is
    # stop_gradient'ed.
    for gi in g:
        np.testing.assert_allclose(gi, jnp.zeros_like(gi), atol=0)


@pytest.mark.parametrize("spec", model.ENV_SPECS, ids=lambda s: s.name)
def test_shapes_for_every_env_spec(spec):
    params = model.init_params(jax.random.PRNGKey(0), spec)
    obs = jnp.zeros((1, spec.obs_dim), jnp.float32)
    (q,) = model.dqn_act(*params, obs)
    assert q.shape == (1, spec.n_actions)
    for p, sh in zip(params, model.param_shapes(spec)):
        assert p.shape == sh
