"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (batch size, obs dim, action count) and value
ranges; assert_allclose at f32 tolerance.  This is the core correctness
signal for the compute layer — the rust side only ever sees these kernels
through the AOT artifacts, so if this file is green the numerics the
coordinator executes are the numerics the paper's DQN computes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.env_step import env_step_cartpole
from compile.kernels.fused_mlp import fused_mlp
from compile.kernels.render import render_cartpole

HIDDEN = 32


def make_params(key, obs_dim, n_actions, hidden=HIDDEN):
    ks = jax.random.split(key, 6)
    u = lambda k, sh: jax.random.uniform(k, sh, jnp.float32, -0.5, 0.5)
    return (
        u(ks[0], (obs_dim, hidden)),
        u(ks[1], (hidden,)),
        u(ks[2], (hidden, hidden)),
        u(ks[3], (hidden,)),
        u(ks[4], (hidden, n_actions)),
        u(ks[5], (n_actions,)),
    )


# ------------------------------------------------------------- fused_mlp


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 64),
    obs_dim=st.integers(1, 48),
    n_actions=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp_forward_matches_ref(batch, obs_dim, n_actions, seed):
    key = jax.random.PRNGKey(seed)
    kp, ko = jax.random.split(key)
    params = make_params(kp, obs_dim, n_actions)
    obs = jax.random.uniform(ko, (batch, obs_dim), jnp.float32, -2.0, 2.0)
    got = fused_mlp(obs, *params)
    want = ref.mlp_forward_ref(obs, *params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 32),
    obs_dim=st.integers(1, 16),
    n_actions=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp_backward_matches_autodiff(batch, obs_dim, n_actions, seed):
    key = jax.random.PRNGKey(seed)
    kp, ko, kd = jax.random.split(key, 3)
    params = make_params(kp, obs_dim, n_actions)
    obs = jax.random.uniform(ko, (batch, obs_dim), jnp.float32, -2.0, 2.0)
    dq = jax.random.uniform(kd, (batch, n_actions), jnp.float32, -1.0, 1.0)

    def loss(*ps):
        return jnp.sum(fused_mlp(obs, *ps) * dq)

    got = jax.grad(loss, argnums=tuple(range(6)))(*params)
    want = ref.mlp_grads_ref(obs, *params, dq)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


def test_fused_mlp_zero_obs_gives_bias_path():
    """With zero weights, output must equal the output-layer bias."""
    obs = jnp.zeros((4, 4), jnp.float32)
    z = jnp.zeros
    b3 = jnp.array([1.0, -2.0], jnp.float32)
    q = fused_mlp(
        obs, z((4, HIDDEN)), z((HIDDEN,)), z((HIDDEN, HIDDEN)),
        z((HIDDEN,)), z((HIDDEN, 2)), b3,
    )
    # elu(0) = 0, so q = 0 @ w3 + b3 = b3 broadcast over the batch.
    np.testing.assert_allclose(q, jnp.broadcast_to(b3, (4, 2)), atol=1e-7)


def test_fused_mlp_jittable():
    params = make_params(jax.random.PRNGKey(0), 4, 2)
    obs = jnp.ones((8, 4), jnp.float32)
    got = jax.jit(fused_mlp)(obs, *params)
    want = ref.mlp_forward_ref(obs, *params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- env_step


@settings(max_examples=25, deadline=None)
@given(batch=st.integers(1, 128), seed=st.integers(0, 2**31 - 1))
def test_env_step_matches_ref(batch, seed):
    key = jax.random.PRNGKey(seed)
    ks, ka = jax.random.split(key)
    state = jax.random.uniform(ks, (batch, 4), jnp.float32, -1.0, 1.0)
    action = jax.random.bernoulli(ka, 0.5, (batch,)).astype(jnp.float32)
    ns, r, d = env_step_cartpole(state, action)
    ns_ref, r_ref, d_ref = ref.env_step_cartpole_ref(state, action)
    np.testing.assert_allclose(ns, ns_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(r, r_ref)
    np.testing.assert_allclose(d, d_ref)


def test_env_step_termination_bounds():
    """States just inside/outside the Gym thresholds terminate correctly."""
    eps = 1e-3
    th = float(ref.THETA_THRESHOLD)
    states = jnp.array(
        [
            [2.4 + eps, 0, 0, 0],    # |x| beyond threshold after step -> done
            [0, 0, th + 0.05, 0],    # theta beyond threshold -> done
            [0, 0, 0, 0],            # nominal -> alive
        ],
        jnp.float32,
    )
    actions = jnp.zeros((3,), jnp.float32)
    _, r, d = env_step_cartpole(states, actions)
    assert d[0] == 1.0
    assert d[1] == 1.0
    assert d[2] == 0.0
    np.testing.assert_allclose(r, jnp.ones(3))


def test_env_step_upright_equilibrium_is_unstable():
    """theta=0 exactly: gravity term vanishes, only the push acts."""
    state = jnp.zeros((1, 4), jnp.float32)
    ns, _, _ = env_step_cartpole(state, jnp.ones((1,), jnp.float32))
    # Push right: x_dot > 0 after one step, theta_dot < 0 (pole lags left).
    assert float(ns[0, 1]) > 0.0
    assert float(ns[0, 3]) < 0.0


# ---------------------------------------------------------------- render


@settings(max_examples=10, deadline=None)
@given(batch=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_render_matches_ref(batch, seed):
    key = jax.random.PRNGKey(seed)
    state = jax.random.uniform(key, (batch, 4), jnp.float32, -1.5, 1.5)
    got = render_cartpole(state)
    want = ref.render_cartpole_ref(state)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_render_centre_scene_geometry():
    """x=0, theta=0: cart centred, pole vertical, intensities correct."""
    frame = np.asarray(render_cartpole(jnp.zeros((1, 4), jnp.float32)))[0]
    from compile.kernels import render as rk

    assert frame.shape == (rk.H, rk.W)
    # Pole pixel straight above the cart centre.
    assert frame[rk.CART_Y - 10, rk.W // 2] == rk.POLE_I
    # Cart body pixel (outside the vertical pole's 1px half-thickness).
    assert frame[rk.CART_Y, rk.W // 2 + 3] == rk.CART_I
    # Track line at its row, far from the cart.
    assert frame[rk.CART_Y + rk.CART_H // 2, 2] == rk.TRACK_I
    # Background corner empty.
    assert frame[0, 0] == 0.0
    # All intensities in [0, 1].
    assert frame.min() >= 0.0 and frame.max() <= 1.0


def test_render_cart_moves_with_x():
    """Cart pixels shift right as world x increases."""
    s0 = jnp.array([[0.0, 0, 0, 0]], jnp.float32)
    s1 = jnp.array([[1.2, 0, 0, 0]], jnp.float32)
    f0 = np.asarray(render_cartpole(s0))[0]
    f1 = np.asarray(render_cartpole(s1))[0]
    c0 = np.argwhere(f0 == 0.6)[:, 1].mean()
    c1 = np.argwhere(f1 == 0.6)[:, 1].mean()
    assert c1 > c0 + 5
