"""AOT layer tests: manifest consistency and HLO-text loadability.

The rust runtime trusts manifest.json for operand ordering; these tests pin
that contract.  Loadability is checked by re-parsing the emitted HLO text
with the local xla_client — the same parser family the rust xla crate uses.
"""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", ART],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            check=True,
        )
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_env_artifacts(manifest):
    for env in ("cartpole", "mountaincar", "acrobot", "pendulum", "multitask"):
        assert f"dqn_act_{env}" in manifest["artifacts"]
        assert f"dqn_train_{env}" in manifest["artifacts"]
    assert "env_step_cartpole" in manifest["artifacts"]
    assert "render_cartpole" in manifest["artifacts"]


def test_artifact_files_exist_and_are_hlo_text(manifest):
    for name, art in manifest["artifacts"].items():
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_train_artifact_operand_counts(manifest):
    for env, spec in manifest["env_specs"].items():
        art = manifest["artifacts"][f"dqn_train_{env}"]
        assert len(art["inputs"]) == 30
        assert len(art["input_names"]) == 30
        assert len(art["outputs"]) == 20
        assert len(art["output_names"]) == 20
        # s operand shape must match the spec.
        s_idx = art["input_names"].index("s")
        assert art["inputs"][s_idx]["shape"] == [
            manifest["hyperparameters"]["batch"], spec["obs_dim"]
        ]


def test_act_artifact_shapes(manifest):
    for env, spec in manifest["env_specs"].items():
        art = manifest["artifacts"][f"dqn_act_{env}"]
        assert art["inputs"][0]["shape"] == [spec["obs_dim"], 32]  # w1
        assert art["inputs"][-1]["shape"] == [1, spec["obs_dim"]]  # obs
        assert art["outputs"][0]["shape"] == [1, spec["n_actions"]]


def test_goldens_present_and_finite(manifest):
    g = manifest["goldens"]
    assert len(g["dqn_act_cartpole"]["q"]) == 2
    assert all(abs(x) < 1e3 for x in g["dqn_act_cartpole"]["q"])
    assert g["dqn_train_cartpole"]["loss"] > 0
    assert g["dqn_train_cartpole"]["t"] == 1.0
    assert g["render_cartpole"]["frame0_sum"] > 0
    assert len(g["env_step_cartpole"]["next_state"]) == 8


def test_hlo_text_reparses(manifest):
    """Round-trip: emitted text parses back into an XlaComputation."""
    from jax._src.lib import xla_client as xc

    for name in ("dqn_act_cartpole", "env_step_cartpole"):
        path = os.path.join(ART, manifest["artifacts"][name]["file"])
        text = open(path).read()
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None


def test_aot_is_idempotent(manifest):
    """Re-running aot must not change artifact mtimes (Makefile contract)."""
    path = os.path.join(ART, "dqn_act_cartpole.hlo.txt")
    before = os.path.getmtime(path)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", ART],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        check=True,
        capture_output=True,
    )
    assert os.path.getmtime(path) == before
