#!/usr/bin/env python3
"""Validate a Chrome trace written by `cairl run --trace`.

Usage: check_trace.py <trace.json> [--require-kinds k1,k2,...]
                      [--expect-server-spans] [--summary FILE]
                      [--min-coverage PCT]

Structural checks (always on):
  * the file parses as Chrome `trace_event` JSON with a non-empty
    `traceEvents` array of complete ("ph":"X") span events;
  * every span carries nonzero `span_id`/`trace_id` args, a known
    kind, and `t_end_ns >= t_start_ns`;
  * the span forest is well-formed: every nonzero `parent` resolves
    to a span recorded under the same trace id.

Stitching checks:
  * `--require-kinds` asserts each named span kind appears at least
    once (the shard-smoke job requires the full client->server chain:
    batch,encode,wire,decode,server_step,reassemble);
  * `--expect-server-spans` asserts spans attributed to a shard
    (args.shard != u32::MAX) exist AND share a trace id with a
    client-side batch span — the cross-shard stitching acceptance.

Attribution checks:
  * `--summary FILE` takes the output of `cairl trace --summarize`:
    every kind named in its table must appear among the trace events,
    and the closing coverage line must be >= `--min-coverage`
    (default 95, the ISSUE-10 acceptance bar).

Exit status: 0 when every check passes, 1 otherwise (each failure is
printed as a GitHub `::error::` annotation).
"""

import json
import re
import sys
from pathlib import Path

SHARD_LOCAL = 0xFFFFFFFF  # u32::MAX — spans recorded by the local process
KNOWN_KINDS = {
    "batch",
    "dispatch",
    "queue",
    "kernel",
    "epilogue",
    "slot",
    "encode",
    "wire",
    "decode",
    "server_step",
    "reassemble",
    "reset",
}
COVERAGE_RE = re.compile(r"critical-path coverage:\s*([0-9.]+)%")


def fail(msg: str) -> None:
    print(f"::error title=trace check::{msg}")


def parse_args(argv: list[str]):
    positional: list[str] = []
    kinds: set[str] = set()
    expect_server = False
    summary: Path | None = None
    min_coverage = 95.0
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg.startswith("--require-kinds"):
            value = arg.split("=", 1)[1] if "=" in arg else argv[i + 1]
            i += 1 if "=" in arg else 2
            kinds.update(k.strip() for k in value.split(",") if k.strip())
        elif arg == "--expect-server-spans":
            expect_server = True
            i += 1
        elif arg.startswith("--summary"):
            value = arg.split("=", 1)[1] if "=" in arg else argv[i + 1]
            i += 1 if "=" in arg else 2
            summary = Path(value)
        elif arg.startswith("--min-coverage"):
            value = arg.split("=", 1)[1] if "=" in arg else argv[i + 1]
            i += 1 if "=" in arg else 2
            min_coverage = float(value)
        else:
            positional.append(arg)
            i += 1
    return positional, kinds, expect_server, summary, min_coverage


def main() -> int:
    positional, require_kinds, expect_server, summary, min_cov = parse_args(
        sys.argv[1:]
    )
    if len(positional) != 1:
        print(__doc__, file=sys.stderr)
        return 2
    trace_path = Path(positional[0])

    try:
        doc = json.loads(trace_path.read_text())
    except (OSError, ValueError) as err:
        fail(f"{trace_path} is not readable JSON: {err}")
        return 1
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail(f"{trace_path} has no traceEvents array")
        return 1

    spans = []
    errors = 0
    for ev in events:
        args = ev.get("args", {})
        if "t_start_ns" not in args:
            continue  # metadata event (process_name)
        spans.append(ev)
        kind = args.get("kind", "")
        if kind not in KNOWN_KINDS:
            fail(f"span {args.get('span_id')} has unknown kind {kind!r}")
            errors += 1
        if not args.get("span_id"):
            fail(f"{kind} span has a zero span_id")
            errors += 1
        if not args.get("trace_id"):
            fail(f"{kind} span {args.get('span_id')} has a zero trace_id")
            errors += 1
        if args.get("t_end_ns", 0) < args.get("t_start_ns", 0):
            fail(f"{kind} span {args.get('span_id')} ends before it starts")
            errors += 1
    if not spans:
        fail(f"{trace_path} contains no span events")
        return 1

    # Parent resolution: every nonzero parent must be a span recorded
    # under the same trace id (the ring is large enough that a smoke
    # run never overflows; a dangling parent means broken propagation).
    by_trace: dict[int, set[int]] = {}
    for ev in spans:
        a = ev["args"]
        by_trace.setdefault(a["trace_id"], set()).add(a["span_id"])
    dangling = 0
    for ev in spans:
        a = ev["args"]
        parent = a.get("parent", 0)
        if parent and parent not in by_trace.get(a["trace_id"], set()):
            if dangling < 5:
                fail(
                    f"{a.get('kind')} span {a['span_id']} parents under "
                    f"{parent}, which is not in trace {a['trace_id']}"
                )
            dangling += 1
    if dangling:
        fail(f"{dangling} span(s) with dangling parents")
        errors += 1

    present_kinds = {ev["args"].get("kind") for ev in spans}
    for kind in sorted(require_kinds):
        if kind not in present_kinds:
            fail(f"required span kind {kind!r} is absent from the trace")
            errors += 1

    if expect_server:
        server = [ev for ev in spans if ev["args"].get("shard") != SHARD_LOCAL]
        batch_traces = {
            ev["args"]["trace_id"]
            for ev in spans
            if ev["args"].get("kind") == "batch"
            and ev["args"].get("shard") == SHARD_LOCAL
        }
        if not server:
            fail("no server-attributed spans (args.shard is local everywhere)")
            errors += 1
        stitched = [
            ev for ev in server if ev["args"]["trace_id"] in batch_traces
        ]
        if server and not stitched:
            fail(
                "server spans never share a trace id with a client batch "
                "span — cross-shard stitching is broken"
            )
            errors += 1
        unstitched = len(server) - len(stitched)
        if unstitched:
            fail(
                f"{unstitched} server span(s) carry a trace id with no "
                "client batch span"
            )
            errors += 1

    if summary is not None:
        try:
            text = summary.read_text()
        except OSError as err:
            fail(f"summary {summary} unreadable: {err}")
            return 1
        table_kinds = {
            line.split()[0]
            for line in text.splitlines()
            if line.split() and line.split()[0] in KNOWN_KINDS
        }
        if not table_kinds:
            fail(f"summary {summary} names no span kinds")
            errors += 1
        for kind in sorted(table_kinds - present_kinds):
            fail(f"summary row {kind!r} has no matching span in the trace")
            errors += 1
        m = COVERAGE_RE.search(text)
        if not m:
            fail(f"summary {summary} has no critical-path coverage line")
            errors += 1
        elif float(m.group(1)) < min_cov:
            fail(
                f"critical-path coverage {m.group(1)}% is below the "
                f"{min_cov:.0f}% acceptance bar"
            )
            errors += 1

    n_server = sum(
        1 for ev in spans if ev["args"].get("shard") != SHARD_LOCAL
    )
    print(
        f"check_trace: {len(spans)} spans, {len(by_trace)} trace id(s), "
        f"{n_server} server-attributed, kinds: "
        f"{','.join(sorted(present_kinds))}"
    )
    if errors:
        print(f"check_trace: {errors} check(s) failed")
        return 1
    print("check_trace: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
