#!/usr/bin/env python3
"""Compare this run's BENCH_ci.json against the previous run's artifact.

Usage: bench_trend.py <current_json> <previous_json_or_dir>
                      [--threshold PCT] [--fallback PATH]

Pairs up the `steps_per_sec_lines` entries of the two documents by their
shape (every digit run collapsed, so timing noise inside a label does
not break the match), extracts the trailing `<number> steps/s` figure,
and emits a GitHub `::warning::` annotation for every line whose
throughput dropped by more than the threshold (default 20%, the
ROADMAP's trend-tracking bar).  Regressions never fail the build — the
CI bench runners are shared and quick-mode budgets are tiny — but the
annotations make a real regression visible on the PR.

`--fallback PATH` names a document to compare against when the previous
artifact is missing or unreadable — in this repo, the tracked
`BENCH_baseline.json` anchor, so the first run on a branch (or a fork
without artifact access) still gets a comparison.  A document carrying
`"baseline": true` downgrades regression `::warning::`s to
`::notice::`s: baseline numbers are machine-dependent estimates, good
for "did throughput fall off a cliff", not percent-level deltas.

Exit status: 0 always, unless the *current* document is unreadable.
A missing previous artifact (first run on a branch, expired retention,
failed download) with no usable fallback degrades gracefully: an
informational `::notice::` annotation, exit 0.  A corrupt/unreadable
previous artifact is treated the same way — only the current document
is load-bearing.
"""

import json
import re
import sys
from pathlib import Path

STEPS_RE = re.compile(r"([0-9][0-9_.,]*(?:e[+-]?[0-9]+)?)\s*steps/s")


def normalise(line: str) -> str:
    """Collapse digit runs so the same workload matches across runs."""
    return re.sub(r"[0-9][0-9_.,]*", "#", line)


def throughput(line: str) -> float | None:
    m = STEPS_RE.search(line)
    if not m:
        return None
    try:
        return float(m.group(1).replace(",", "").replace("_", ""))
    except ValueError:
        return None


def load_doc(path: Path) -> dict:
    doc = json.loads(path.read_text())
    if not isinstance(doc, dict):
        raise ValueError(f"{path}: expected a JSON object, got {type(doc).__name__}")
    return doc


def lines_table(doc: dict) -> dict[str, float]:
    table: dict[str, float] = {}
    for line in doc.get("steps_per_sec_lines", []):
        value = throughput(line)
        if value is not None and value > 0:
            # Last write wins on duplicate shapes; that keeps pairing
            # stable without inventing per-line identifiers.
            table[normalise(line)] = value
    return table


def is_shard_row(key: str) -> bool:
    """Sharded bench rows carry 'shard' in their label (fig1_console
    prints `EnvPool shard-2 (...) ... steps/s`)."""
    return "shard" in key.lower()


def is_script_row(key: str) -> bool:
    """Script-runner bench rows carry 'bounce' in their label
    (ablation_dispatch prints `bounce tree-walk ... steps/s`)."""
    return "bounce" in key.lower()


def roofline_table(doc: dict) -> dict[str, float]:
    """Key the `roofline` block rows by env/kernel/lane-count.  The
    line matcher collapses digit runs, which would merge every lane
    width of a sweep into one key — here the digits are the identity,
    so the block is paired exactly."""
    table: dict[str, float] = {}
    for row in doc.get("roofline", []):
        try:
            key = f"{row['env']}/{row.get('kernel', 'fused')}@{int(row['lanes'])}"
            value = float(row["lane_steps_per_sec"])
        except (KeyError, TypeError, ValueError):
            continue
        if value > 0:
            table[key] = value
    return table


def compare_roofline(
    current_doc: dict, previous_doc: dict, threshold: float, is_baseline: bool
) -> int:
    """Pair roofline rows across runs; returns the regression count.
    A previous artifact without the block predates the sweep — notice
    and skip, same as the topology/script-runner markers."""
    current = roofline_table(current_doc)
    if not current:
        return 0
    if "roofline" not in previous_doc:
        print(
            "::notice title=bench trend::previous BENCH_ci.json predates "
            f"the roofline block — skipping {len(current)} kernel-sweep "
            "row(s) that have no baseline yet (they compare from the "
            "next run)"
        )
        return 0
    previous = roofline_table(previous_doc)
    shared = sorted(set(current) & set(previous))
    print(f"bench_trend: comparing {len(shared)} shared roofline rows")
    regressions = 0
    for key in shared:
        old, new = previous[key], current[key]
        delta = 100.0 * (new - old) / old
        marker = ""
        if delta <= -threshold:
            regressions += 1
            marker = "  <-- REGRESSION"
            title = "roofline throughput regression"
            severity = "warning"
            if is_baseline:
                severity = "notice"
                title += " (vs tracked baseline estimates)"
            print(
                f"::{severity} title={title}::"
                f"{key} dropped {-delta:.0f}% "
                f"({old:.0f} -> {new:.0f} lane-steps/s)"
            )
        print(f"  {delta:+6.1f}%  {old:>12.0f} -> {new:>12.0f}  {key}{marker}")
    return regressions


def find_previous(arg: Path) -> Path | None:
    if arg.is_file():
        return arg
    if arg.is_dir():
        hits = sorted(arg.glob("**/BENCH_ci.json"))
        if hits:
            return hits[0]
    return None


def parse_args(argv: list[str]) -> tuple[list[str], float, Path | None]:
    """Positional args, --threshold, --fallback.  Both flags accept
    `--flag value` and `--flag=value` spellings."""
    positional: list[str] = []
    threshold = 20.0
    fallback: Path | None = None
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg.startswith("--threshold"):
            value = arg.split("=", 1)[1] if "=" in arg else argv[i + 1]
            i += 1 if "=" in arg else 2
            threshold = float(value)
        elif arg.startswith("--fallback"):
            value = arg.split("=", 1)[1] if "=" in arg else argv[i + 1]
            i += 1 if "=" in arg else 2
            fallback = Path(value)
        else:
            positional.append(arg)
            i += 1
    return positional, threshold, fallback


def main() -> int:
    args, threshold, fallback = parse_args(sys.argv[1:])
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2

    current_path = Path(args[0])
    current_doc = load_doc(current_path)
    current = lines_table(current_doc)

    previous_doc: dict | None = None
    previous_path = find_previous(Path(args[1]))
    if previous_path is not None:
        try:
            previous_doc = load_doc(previous_path)
        except (OSError, ValueError, AttributeError, TypeError) as err:
            # ValueError covers json.JSONDecodeError; AttributeError/
            # TypeError cover well-formed JSON of the wrong shape (e.g.
            # a bare null or list from a truncated upload).
            print(
                "::notice title=bench trend::previous BENCH_ci.json at "
                f"{previous_path} is unreadable ({err})"
            )
            previous_doc = None
    if previous_doc is None and fallback is not None:
        try:
            previous_doc = load_doc(fallback)
            previous_path = fallback
            print(
                "::notice title=bench trend::no previous run artifact — "
                f"comparing against the tracked anchor {fallback}"
            )
        except (OSError, ValueError, AttributeError, TypeError) as err:
            print(
                f"::notice title=bench trend::fallback {fallback} is "
                f"unreadable ({err})"
            )
            previous_doc = None
    if previous_doc is None:
        print(
            "::notice title=bench trend::no previous BENCH_ci.json artifact "
            f"under {args[1]!r} (first run on this branch, or retention "
            "expired) — nothing to compare against, skipping"
        )
        return 0
    previous = lines_table(previous_doc)
    # Baseline anchors carry estimated, machine-dependent figures; a
    # delta against them is a sanity check, not a regression signal.
    is_baseline = bool(previous_doc.get("baseline"))

    # Sharded rows (the `topology` column) only exist from the shard-PR
    # onward.  A previous artifact that predates the field has no
    # baseline for them — drop the current shard rows from the pairing
    # and say so, instead of silently reporting fewer shared workloads.
    if current_doc.get("topologies") and "topologies" not in previous_doc:
        n_shard = sum(1 for key in current if is_shard_row(key))
        if n_shard:
            print(
                "::notice title=bench trend::previous BENCH_ci.json predates "
                f"the topology field — skipping {n_shard} sharded row(s) "
                "that have no baseline yet (they compare from the next run)"
            )
            current = {k: v for k, v in current.items() if not is_shard_row(k)}

    # Same story for the script-runner rows (tree-walk / bytecode /
    # batched SoA on bounce.mpy): they only exist from the bytecode-VM
    # PR onward, so a previous artifact without the marker field has no
    # baseline for them yet.
    if current_doc.get("script_runners") and "script_runners" not in previous_doc:
        n_script = sum(1 for key in current if is_script_row(key))
        if n_script:
            print(
                "::notice title=bench trend::previous BENCH_ci.json predates "
                f"the script-runner rows — skipping {n_script} row(s) "
                "that have no baseline yet (they compare from the next run)"
            )
            current = {k: v for k, v in current.items() if not is_script_row(k)}

    shared = sorted(set(current) & set(previous))
    print(
        f"bench_trend: comparing {len(shared)} shared workloads "
        f"({len(current)} current, {len(previous)} previous, "
        f"threshold {threshold:.0f}%)"
    )
    regressions = 0
    for key in shared:
        old, new = previous[key], current[key]
        delta = 100.0 * (new - old) / old
        marker = ""
        if delta <= -threshold:
            regressions += 1
            marker = "  <-- REGRESSION"
            title = "bench throughput regression"
            if is_shard_row(key):
                # Transport overhead regressions get their own label so
                # shard-layer changes are attributable at a glance.
                title = "sharded bench throughput regression"
            severity = "warning"
            if is_baseline:
                severity = "notice"
                title += " (vs tracked baseline estimates)"
            print(
                f"::{severity} title={title}::"
                f"{key.strip()} dropped {-delta:.0f}% "
                f"({old:.0f} -> {new:.0f} steps/s)"
            )
        print(f"  {delta:+6.1f}%  {old:>12.0f} -> {new:>12.0f}  {key.strip()}{marker}")
    regressions += compare_roofline(current_doc, previous_doc, threshold, is_baseline)
    if regressions:
        print(f"bench_trend: {regressions} workload(s) regressed > {threshold:.0f}%")
    else:
        print("bench_trend: no regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
