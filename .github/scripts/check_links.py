#!/usr/bin/env python3
"""Check that every relative markdown link resolves to a real file.

Usage: check_links.py <file-or-dir> [<file-or-dir> ...]

Scans the given markdown files (directories are searched recursively
for *.md) for inline links and images — `[text](target)` — and fails
listing every target that does not exist on disk.  External links
(http/https/mailto) and pure in-page anchors (`#section`) are skipped;
a `path#fragment` target is checked for the path only.  This keeps the
README and docs/ cross-reference web (ARCHITECTURE.md, the wire spec,
source-file pointers) from silently rotting as files move.

Exit status: 0 when every link resolves, 1 otherwise, 2 on bad usage.
"""

import re
import sys
from pathlib import Path

# Inline link or image: [text](target) / ![alt](target).  Nested
# brackets in the text are rare in this repo and not worth a full
# CommonMark parser; the target group stops at the first unbalanced ')'.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^()\s]+(?:\([^()]*\)[^()\s]*)*)\)")

SKIP_PREFIXES = ("http://", "https://", "mailto:")


def collect(args: list[str]) -> list[Path]:
    files: list[Path] = []
    for arg in args:
        path = Path(arg)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.md")))
        elif path.is_file():
            files.append(path)
        else:
            print(f"check_links: no such file or directory: {arg}", file=sys.stderr)
            sys.exit(2)
    return files


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2

    broken: list[tuple[Path, str]] = []
    checked = 0
    for md in collect(sys.argv[1:]):
        text = md.read_text(errors="replace")
        # Fenced code blocks contain things that look like links
        # (e.g. JSON with brackets); drop them before matching.
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        for match in LINK_RE.finditer(text):
            target = match.group(1)
            if target.startswith(SKIP_PREFIXES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            checked += 1
            if not (md.parent / rel).exists():
                broken.append((md, target))

    if broken:
        for md, target in broken:
            print(f"::error file={md}::broken relative link: {target}")
        print(f"check_links: {len(broken)} broken link(s) out of {checked} checked")
        return 1
    print(f"check_links: all {checked} relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
