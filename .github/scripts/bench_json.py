#!/usr/bin/env python3
"""Assemble the per-PR machine-readable bench artifact (BENCH_ci.json).

Usage: bench_json.py <results_dir> <out_json>

Collects every CSV the bench binaries wrote under <results_dir> (the
CsvLogger outputs: fig1_console.csv, fig1_executors.csv,
ablation_dispatch.csv, ...) into one JSON document, plus every
steps/sec line from the smoke log, stamped with the commit under test.
CI uploads the result as a build artifact so the perf trajectory of the
executor layer is inspectable PR over PR without re-running anything.
"""

import csv
import json
import os
import re
import sys
from pathlib import Path


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    results_dir = Path(sys.argv[1])
    out_path = Path(sys.argv[2])

    doc = {
        "schema": "cairl-bench-ci/v1",
        "commit": os.environ.get("GITHUB_SHA", "unknown"),
        "ref": os.environ.get("GITHUB_REF", "unknown"),
        "run_id": os.environ.get("GITHUB_RUN_ID", "unknown"),
        "quick_mode": os.environ.get("CAIRL_BENCH_QUICK", "") == "1",
        "tables": {},
        "steps_per_sec_lines": [],
    }

    kernels: set[str] = set()
    topologies: set[str] = set()
    script_runners: set[str] = set()
    for csv_path in sorted(results_dir.glob("*.csv")):
        with csv_path.open(newline="") as fh:
            rows = list(csv.DictReader(fh))
        doc["tables"][csv_path.stem] = rows
        kernels.update(row["kernel"] for row in rows if row.get("kernel"))
        topologies.update(row["topology"] for row in rows if row.get("topology"))
        script_runners.update(
            row["variant"]
            for row in rows
            if row.get("variant", "").startswith("bounce")
        )
    # Which stepping kernels the bench rows cover (scalar/fused), so the
    # trend tooling and humans compare like against like across runs.
    doc["kernel_modes"] = sorted(kernels)
    # Which execution topologies the rows cover ("local" vs "shard-N"):
    # the trend tooling uses its presence to tell whether a previous
    # artifact predates the sharded rows entirely.
    doc["topologies"] = sorted(topologies)
    # Which script-runner rows exist (tree-walk AST / bytecode VM /
    # batched SoA on bounce.mpy): like `topologies`, its presence tells
    # the trend tooling whether a previous artifact predates them.
    doc["script_runners"] = sorted(script_runners)

    # Telemetry overhead: the ablation's metrics-on/off A/B on the
    # 32-lane fused pool, surfaced as its own block so the <2% budget is
    # trackable PR over PR (absent in artifacts predating telemetry).
    ablation = doc["tables"].get("ablation_dispatch", [])
    metrics_ab = {
        row["variant"]: float(row["ns_per_step"])
        for row in ablation
        if row.get("variant") in ("pool-32-metrics-on", "pool-32-metrics-off")
        and row.get("ns_per_step")
    }
    if len(metrics_ab) == 2:
        ns_on = metrics_ab["pool-32-metrics-on"]
        ns_off = metrics_ab["pool-32-metrics-off"]
        doc["metrics"] = {
            "ns_per_step_on": ns_on,
            "ns_per_step_off": ns_off,
            "overhead_pct": round(100.0 * (ns_on / ns_off - 1.0), 3),
        }

    # Tracing overhead: the same A/B with the span recorder
    # (`cairl run --trace`) on vs off, sharing the <2% budget (absent
    # in artifacts predating distributed tracing).
    trace_ab = {
        row["variant"]: float(row["ns_per_step"])
        for row in ablation
        if row.get("variant") in ("pool-32-trace-on", "pool-32-trace-off")
        and row.get("ns_per_step")
    }
    if len(trace_ab) == 2:
        ns_on = trace_ab["pool-32-trace-on"]
        ns_off = trace_ab["pool-32-trace-off"]
        doc["trace"] = {
            "ns_per_step_on": ns_on,
            "ns_per_step_off": ns_off,
            "overhead_pct": round(100.0 * (ns_on / ns_off - 1.0), 3),
        }

    # Roofline: the classic-control fused kernels swept across lane
    # widths (results/roofline.csv), lifted into a keyed block so
    # bench_trend.py can pair rows across runs without relying on the
    # digit-collapsing line matcher (lane counts are load-bearing
    # digits here).  Absent in artifacts predating the sweep.
    roofline_rows = doc["tables"].get("roofline", [])
    roofline = []
    for row in roofline_rows:
        try:
            roofline.append(
                {
                    "env": row["env"],
                    "lanes": int(row["lanes"]),
                    "kernel": row.get("kernel", "fused"),
                    "ns_per_lane_step": float(row["ns_per_lane_step"]),
                    "lane_steps_per_sec": float(row["lane_steps_per_sec"]),
                }
            )
        except (KeyError, ValueError):
            continue
    if roofline:
        doc["roofline"] = roofline

    log_path = results_dir / "bench_smoke.log"
    if log_path.exists():
        pattern = re.compile(r"steps/s")
        with log_path.open(errors="replace") as fh:
            doc["steps_per_sec_lines"] = [
                line.rstrip("\n") for line in fh if pattern.search(line)
            ]

    out_path.write_text(json.dumps(doc, indent=2) + "\n")
    n_tables = len(doc["tables"])
    n_lines = len(doc["steps_per_sec_lines"])
    modes = ",".join(doc["kernel_modes"]) or "none"
    topos = ",".join(doc["topologies"]) or "none"
    print(
        f"wrote {out_path}: {n_tables} tables, {n_lines} steps/sec lines, "
        f"kernel modes: {modes}, topologies: {topos}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
