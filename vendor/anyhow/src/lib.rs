//! Minimal offline shim of the `anyhow` crate.
//!
//! The launcher (`rust/src/main.rs`) only needs a message-carrying error
//! type, the `anyhow!` / `bail!` macros, `Context` on `Result`, and a
//! `Result` alias whose `Debug` output is the human-readable message
//! (what `fn main() -> Result<()>` prints on exit).  This shim provides
//! exactly that surface with no dependencies; swap the path dependency
//! for the real crate when a registry is available — call sites are
//! source-compatible.

use std::fmt;

/// A message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

// `fn main() -> Result<()>` prints errors with `{:?}`; match anyhow by
// showing the plain message rather than a struct dump.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow`-shaped result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to an error, anyhow-style: `"context: cause"`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u64> {
        s.parse::<u64>().with_context(|| format!("bad number {s:?}"))
    }

    #[test]
    fn context_prefixes_the_cause() {
        let err = parse("xyz").unwrap_err();
        let text = format!("{err}");
        assert!(text.starts_with("bad number \"xyz\": "), "{text}");
        assert_eq!(format!("{err:?}"), text, "Debug matches Display");
    }

    #[test]
    fn ok_passes_through() {
        assert_eq!(parse("17").unwrap(), 17);
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Err(anyhow!("plain {}", "message"))
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", f(false).unwrap_err()), "plain message");
    }

    #[test]
    fn std_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
    }
}
