//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The toolkit's runtime layer (`cairl::runtime`) is written against the
//! xla-rs API.  This image carries no libxla / PJRT plugin, so this crate
//! provides the same surface with two behaviours:
//!
//! * **Host-side [`Literal`]s are fully functional** — construction,
//!   reshape, readback.  Everything that doesn't need a device works and
//!   is unit-tested.
//! * **Device entry points fail honestly** — [`PjRtClient::cpu`] returns
//!   an error, which makes every executable/buffer type uninhabited.
//!   Callers (see `cairl::runtime::pjrt::Runtime::new`) surface that
//!   error and the toolkit's artifact-dependent paths skip gracefully.
//!
//! To run the real artifacts, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings; no `cairl` source changes
//! are needed — the signatures below match xla-rs.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: a message, `Display`-compatible with xla-rs errors.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this build (offline `xla` stub; \
         point the xla path dependency at the real bindings to enable \
         artifact execution)"
    ))
}

/// Element types a [`Literal`] can hold.  Mirrors xla-rs's sealed
/// element-type trait for the two dtypes the toolkit marshals.
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> Buf;
    fn unwrap(buf: &Buf) -> Option<Vec<Self>>;
}

/// Typed host buffer backing a [`Literal`].
#[derive(Clone, Debug)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
        }
    }
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Buf {
        Buf::F32(data.to_vec())
    }
    fn unwrap(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::F32(v) => Some(v.clone()),
            Buf::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Buf {
        Buf::I32(data.to_vec())
    }
    fn unwrap(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            Buf::F32(_) => None,
        }
    }
}

/// A host-side tensor literal (fully functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            buf: T::wrap(data),
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            buf: T::wrap(&[v]),
            dims: Vec::new(),
        }
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.buf.len()
    }

    /// Same data, new logical shape (element counts must agree).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.buf.len() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.buf.len()
            )));
        }
        Ok(Literal {
            buf: self.buf.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Read the elements back (row-major), erroring on a dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf)
            .ok_or_else(|| Error("to_vec: literal holds a different dtype".into()))
    }

    /// Decompose a tuple literal.  The stub never produces tuples (they
    /// only come back from device execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("to_tuple: not a tuple literal".into()))
    }

    /// Logical dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Uninhabited marker: device objects cannot exist in the stub, so every
/// method on them is statically unreachable (`match void {}`).
#[derive(Clone, Copy, Debug)]
pub enum Void {}

/// A PJRT device handle (uninhabited in the stub).
#[derive(Debug)]
pub struct PjRtDevice {
    #[allow(dead_code)] // uninhabitedness marker; only matched in richer types
    void: Void,
}

/// A PJRT client (uninhabited in the stub — [`PjRtClient::cpu`] errors).
#[derive(Debug)]
pub struct PjRtClient {
    void: Void,
}

impl PjRtClient {
    /// Create a CPU PJRT client.  Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.void {}
    }

    /// Upload a host tensor to the device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        match self.void {}
    }
}

/// A device-resident buffer (uninhabited in the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    void: Void,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.void {}
    }
}

/// A compiled executable (uninhabited in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    void: Void,
}

impl PjRtLoadedExecutable {
    /// Execute with literal operands; one result vector per device.
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.void {}
    }

    /// Execute with device-buffer operands.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.void {}
    }
}

/// Parsed HLO module (the stub carries no parser — loading errors).
#[derive(Debug)]
pub struct HloModuleProto {
    void: Void,
}

impl HloModuleProto {
    /// Parse HLO text from a file.  Always fails in the stub (there is
    /// no XLA parser to call), but client construction fails first in
    /// every toolkit code path.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation {
    #[allow(dead_code)] // uninhabitedness marker; constructed from no value
    void: Void,
}

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.void {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_dtype_mismatch_errors() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn scalar_has_rank_zero() {
        let s = Literal::scalar(7.5f32);
        assert_eq!(s.element_count(), 1);
        assert!(s.dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn bad_reshape_errors() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_decomposition_errors_on_stub_literals() {
        assert!(Literal::vec1(&[0.0f32]).to_tuple().is_err());
    }

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub has no PJRT");
        assert!(err.to_string().contains("PJRT is unavailable"), "{err}");
    }

    #[test]
    fn hlo_parsing_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("artifacts/x.hlo.txt").is_err());
    }
}
